//! Multi-shard tensor/pipeline-parallel serving (DESIGN.md §16): one
//! seeded model split across N [`HostBackend`] instances behind the
//! single-backend [`InferenceBackend`] contract, so the coordinator's
//! serving loop runs unchanged.
//!
//! Two axes of parallelism, both merged losslessly:
//!
//! * **Pipeline-parallel partition ownership** — the model's macro
//!   partitions are assigned to shards in contiguous near-even ranges
//!   ([`ShardPlan`]); a shard executes every layer of its partitions
//!   and holds those layers' KV in its *own* tiered
//!   [`KvStore`](crate::kvcache::KvStore) (per-shard DR-eDRAM /
//!   external-DRAM tiers and retention clock), the software analogue
//!   of one CiROM chip per partition group.
//! * **Tensor-parallel LM head** — the head's ternary projection is
//!   column-split across shards ([`TernaryMatrix::submatrix`]); each
//!   shard computes its partial GEMV in exact i64 and the merge is
//!   plain concatenation, so any shard count reproduces the unsharded
//!   logits *bit-exactly* (the same argument the standalone
//!   [`sharded_gemv`] / [`sharded_gemm`] kernels make against the
//!   golden [`ref_gemv`](crate::bitnet::ref_gemv)).
//!
//! The governing rule is **invariant 12**, the pool invariant
//! (DESIGN.md §12) extended one level up: shard count changes
//! throughput and placement — per-shard KV tiers, per-shard event /
//! energy / adapter accounting — but never tokens. Every weight matrix
//! is fabricated identically on every shard from the shared seed
//! (weights are ROM; replicating a mask set costs no reloads), KV rows
//! live on exactly one shard, and all cross-shard reductions are exact
//! integer sums or order-fixed concatenations.
//!
//! What deliberately does not shard: the content-hash prefix cache
//! (DESIGN.md §15) binds whole-prompt blocks into *every* layer's
//! table, which is incompatible with shard-local layer ownership —
//! [`ShardedBackend`] reports every prefix bind as a miss, trading the
//! traffic win for unchanged tokens (invariants 11 ∧ 12). Event mode
//! routes the LM head through shard 0 whole, so merged
//! [`EventCounters`] still sum to the unsharded totals.
//!
//! Property coverage lives in `tests/shard_props.rs`: partial-merge ≡
//! unsharded ≡ `ref_gemv` over uneven splits, served traces
//! bit-identical across `--shards 1/2/3/5` × thread widths, and
//! per-shard counters summing to the unsharded run's totals.

use anyhow::{anyhow, Result};

use crate::bitnet::{absmax_quantize, KernelCtx, KernelPath, TernaryMatrix};
use crate::cirom::EventCounters;
use crate::config::{ModelConfig, ServeConfig};
use crate::kvcache::KvStoreStats;
use crate::lora::LoraServeStats;
use crate::util::pool::Pool;

use super::backend::{DecodeEntry, InferenceBackend, KvControl, Logits, SequenceState, ServeTuning};
use super::host::{rmsnorm, HostBackend, HostState};

/// Contiguous near-even assignment of `n_items` items to shards: the
/// first `n_items % n_shards` shards own one extra item, so any item
/// count splits over any shard count (ranges may be empty when there
/// are more shards than items). Used for both partition ownership and
/// tensor-parallel column splits; the fixed first-heavy order is what
/// makes concatenation-order merges deterministic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    ranges: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// Split `n_items` items into `n_shards` contiguous near-even
    /// ranges (`n_shards` is clamped to at least 1).
    pub fn near_even(n_items: usize, n_shards: usize) -> Self {
        let k = n_shards.max(1);
        let base = n_items / k;
        let rem = n_items % k;
        let mut ranges = Vec::with_capacity(k);
        let mut lo = 0usize;
        for s in 0..k {
            let len = base + usize::from(s < rem);
            ranges.push((lo, lo + len));
            lo += len;
        }
        ShardPlan { ranges }
    }

    /// Number of shards in the plan.
    pub fn n_shards(&self) -> usize {
        self.ranges.len()
    }

    /// Half-open item range `[lo, hi)` owned by shard `s`.
    pub fn range(&self, s: usize) -> (usize, usize) {
        self.ranges[s]
    }

    /// All ranges in shard order.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// The shard owning `item`.
    ///
    /// # Panics
    /// If `item` is outside every range of the plan.
    pub fn owner(&self, item: usize) -> usize {
        self.ranges
            .iter()
            .position(|&(lo, hi)| (lo..hi).contains(&item))
            .unwrap_or_else(|| panic!("item {item} outside the shard plan"))
    }
}

/// Tensor-parallel GEMV: column-shard `w` over `n_shards` near-even
/// contiguous ranges, compute each shard's partial on its submatrix in
/// exact i64, merge by concatenation. Bit-identical to the unsharded
/// [`TernaryMatrix::gemv`] (and hence to the golden
/// [`ref_gemv`](crate::bitnet::ref_gemv)) at *any* shard count —
/// integer partials over disjoint output columns have nothing to
/// round. Shards assigned zero columns contribute nothing.
pub fn sharded_gemv(x: &[i32], w: &TernaryMatrix, n_shards: usize, pool: &Pool) -> Vec<i64> {
    let plan = ShardPlan::near_even(w.cols, n_shards);
    let mut y = Vec::with_capacity(w.cols);
    for s in 0..plan.n_shards() {
        let (c0, c1) = plan.range(s);
        if c0 == c1 {
            continue;
        }
        let sub = w.submatrix(0, w.rows, c0, c1);
        y.extend(KernelCtx::new(*pool).gemv(sub.bitplanes(), x));
    }
    y
}

/// Batched twin of [`sharded_gemv`]: every activation row through the
/// same column split, partials concatenated per row. Bit-identical to
/// [`TernaryMatrix::gemm`] at any shard count.
pub fn sharded_gemm(
    xs: &[Vec<i32>],
    w: &TernaryMatrix,
    n_shards: usize,
    pool: &Pool,
) -> Vec<Vec<i64>> {
    let plan = ShardPlan::near_even(w.cols, n_shards);
    let mut out: Vec<Vec<i64>> = xs.iter().map(|_| Vec::with_capacity(w.cols)).collect();
    for s in 0..plan.n_shards() {
        let (c0, c1) = plan.range(s);
        if c0 == c1 {
            continue;
        }
        let sub = w.submatrix(0, w.rows, c0, c1);
        for (row, part) in out.iter_mut().zip(KernelCtx::new(*pool).gemm(sub.bitplanes(), xs)) {
            row.extend(part);
        }
    }
    out
}

/// Per-sequence state of a [`ShardedBackend`]: one [`HostState`] per
/// shard (each holding only its shard's layers' KV in that shard's
/// store) plus the coordinator-visible decode progress. The inner
/// states' own `pos`/`prompt_len` are never used — partition stages
/// take explicit positions, and the wrapper is the single source of
/// truth the serving loop reads.
pub struct ShardedState {
    states: Vec<HostState>,
    pos: usize,
    prompt_len: usize,
}

impl SequenceState for ShardedState {
    fn pos(&self) -> usize {
        self.pos
    }
    fn set_pos(&mut self, pos: usize) {
        self.pos = pos;
    }
    fn prompt_len(&self) -> usize {
        self.prompt_len
    }
    fn set_prompt_len(&mut self, len: usize) {
        self.prompt_len = len;
    }
}

/// N same-seed [`HostBackend`] shards behind one [`InferenceBackend`]
/// (module docs): pipeline-parallel partition ownership over per-shard
/// KV stores, a tensor-parallel exact-i64 LM head, and per-shard
/// event / energy / adapter accounting whose merged view sums to the
/// unsharded totals. Invariant 12: shard count never changes tokens.
pub struct ShardedBackend {
    shards: Vec<HostBackend>,
    /// Partition → shard ownership (contiguous near-even).
    parts: ShardPlan,
    /// Tensor-parallel head column splits (`None` for shards assigned
    /// zero vocabulary columns). `submatrix` preserves the matrix
    /// scale, so the merged rescale is bit-identical to unsharded.
    head_cols: Vec<Option<TernaryMatrix>>,
    /// True when the shards run the event-counted cirom path: the head
    /// then executes whole on shard 0 (its event tally must land in
    /// exactly one shard for the merged counters to sum correctly).
    event_mode: bool,
}

impl ShardedBackend {
    /// Wrap pre-built shards (all fabricated from the same model +
    /// seed — validated; weight equality follows from deterministic
    /// fabrication). Shard count must not exceed the model's partition
    /// count, so every shard owns at least one pipeline stage. Shards
    /// must agree on event mode and on whether they carry an adapter
    /// registry (binds fan out to every shard).
    pub fn from_shards(shards: Vec<HostBackend>) -> Result<Self> {
        anyhow::ensure!(!shards.is_empty(), "a sharded backend needs at least one shard");
        let model = shards[0].model().clone();
        anyhow::ensure!(
            shards.len() <= model.n_partitions,
            "{} shards exceed the model's {} partitions",
            shards.len(),
            model.n_partitions
        );
        let event_mode = shards[0].events().is_some();
        for (i, s) in shards.iter().enumerate().skip(1) {
            anyhow::ensure!(s.model() == &model, "shard {i} runs a different model than shard 0");
            anyhow::ensure!(
                s.seed() == shards[0].seed(),
                "shard {i} was fabricated from a different weight seed than shard 0"
            );
            anyhow::ensure!(
                s.events().is_some() == event_mode,
                "shard {i} disagrees with shard 0 on event mode"
            );
            anyhow::ensure!(
                s.adapters().is_some() == shards[0].adapters().is_some(),
                "shard {i} disagrees with shard 0 on adapter serving"
            );
        }
        let parts = ShardPlan::near_even(model.n_partitions, shards.len());
        let head_plan = ShardPlan::near_even(model.vocab_size, shards.len());
        let head_w = shards[0].head_weights();
        let head_cols = (0..shards.len())
            .map(|s| {
                let (c0, c1) = head_plan.range(s);
                (c1 > c0).then(|| head_w.submatrix(0, head_w.rows, c0, c1))
            })
            .collect();
        Ok(ShardedBackend {
            shards,
            parts,
            head_cols,
            event_mode,
        })
    }

    /// Fabricate `n_shards` same-seed shards on the bitplane fast path
    /// (`n_shards` is clamped to `1..=model.n_partitions`; `--shards 1`
    /// is the unsharded topology behind the same type).
    pub fn new(model: ModelConfig, seed: u64, n_shards: usize) -> Result<Self> {
        let n = n_shards.clamp(1, model.n_partitions.max(1));
        let shards = (0..n)
            .map(|_| HostBackend::new(model.clone(), seed))
            .collect::<Result<Vec<_>>>()?;
        Self::from_shards(shards)
    }

    /// The partition → shard ownership plan.
    pub fn partition_plan(&self) -> &ShardPlan {
        &self.parts
    }

    /// Per-shard measured KV-tier statistics, shard order. The merged
    /// [`KvControl::kv_stats`] view is the field-wise sum.
    pub fn shard_kv_stats(&self) -> Vec<KvStoreStats> {
        self.shards
            .iter()
            .map(|s| s.kv_stats().expect("host shards measure KV stats"))
            .collect()
    }

    /// Per-shard adapter-serving statistics, shard order (`None`
    /// without a registry).
    pub fn shard_lora_stats(&self) -> Option<Vec<LoraServeStats>> {
        self.shards.iter().map(|s| s.lora_stats()).collect()
    }

    /// Merged circuit-event counters across every shard (event mode
    /// only): layer projections tally in their owning shard, the head
    /// in shard 0, so the integer sum equals the unsharded totals.
    pub fn events(&self) -> Option<EventCounters> {
        let mut total = self.shards[0].events()?;
        for s in &self.shards[1..] {
            total.merge(&s.events()?);
        }
        Some(total)
    }

    /// Layer range `[l0, l1)` owned by shard `s` (its partitions ×
    /// layers-per-partition).
    fn layer_range(&self, s: usize) -> (usize, usize) {
        let lpp = self.shards[0].model().layers_per_partition();
        let (p0, p1) = self.parts.range(s);
        (p0 * lpp, p1 * lpp)
    }

    /// Tensor-parallel LM head (fast path): quantize the normed row
    /// once, run each shard's column submatrix GEMV in exact i64,
    /// concatenate, rescale — bit-identical to the unsharded
    /// projection because the partials are disjoint integer columns
    /// under the same scale.
    fn tp_head(&self, row: &[f32]) -> Logits {
        let xn = rmsnorm(row);
        let q = absmax_quantize(&xn, self.shards[0].model().act_bits);
        let ctx = KernelCtx::new(Pool::new(self.shards[0].threads()))
            .with_path(self.shards[0].kernel_path());
        let mut data = Vec::with_capacity(self.shards[0].model().vocab_size);
        for w in self.head_cols.iter().flatten() {
            let s = q.scale * w.scale;
            data.extend(ctx.gemv(w.bitplanes(), &q.values).into_iter().map(|v| v as f32 * s));
        }
        Logits::new(data)
    }
}

impl KvControl for ShardedBackend {
    type Seq = ShardedState;

    /// Size every shard's store for the deployment: each shard gets
    /// the full configured on-die capacity for its own layers (one
    /// modeled chip per shard, the scale-out premise).
    fn configure_kv(&self, serve: &ServeConfig) -> Result<()> {
        for s in &self.shards {
            s.configure_kv(serve)?;
        }
        Ok(())
    }

    fn advance_kv_clock(&self, now_s: f64) {
        for s in &self.shards {
            s.advance_kv_clock(now_s);
        }
    }

    /// Advance one shard's retention clock independently — what lets a
    /// shard-local retention storm (DESIGN.md §13 under §16) expire
    /// rows on exactly one modeled chip.
    fn advance_kv_clock_shard(&self, shard: usize, now_s: f64) {
        self.shards[shard].advance_kv_clock(now_s);
    }

    /// Field-wise sum of the per-shard stats: access counts, failures,
    /// energies and occupancy gauges add; the config gauges
    /// (`quant_bits`, `block_tokens`) are shard 0's (identical
    /// everywhere). Placement-invariant combined counters sum exactly
    /// to the unsharded run's totals; the tier *split* may differ —
    /// per-shard stores have more on-die headroom per layer.
    fn kv_stats(&self) -> Option<KvStoreStats> {
        let mut total = self.shards[0].kv_stats()?;
        for s in &self.shards[1..] {
            let st = s.kv_stats()?;
            total.accesses.ondie_reads += st.accesses.ondie_reads;
            total.accesses.ondie_writes += st.accesses.ondie_writes;
            total.accesses.external_reads += st.accesses.external_reads;
            total.accesses.external_writes += st.accesses.external_writes;
            total.evictions += st.evictions;
            total.spilled_early_blocks += st.spilled_early_blocks;
            total.retention_failures += st.retention_failures;
            total.explicit_refreshes += st.explicit_refreshes;
            total.edram_energy_j += st.edram_energy_j;
            total.dram_energy_j += st.dram_energy_j;
            total.ondie_blocks_in_use += st.ondie_blocks_in_use;
            total.ondie_block_capacity += st.ondie_block_capacity;
            total.prefix_hits += st.prefix_hits;
            total.prefix_bound_tokens += st.prefix_bound_tokens;
            total.cow_forks += st.cow_forks;
        }
        Some(total)
    }

    /// Reserve the round's pages on each shard for *its own* layer
    /// range only — placement stays a coordinator-side mutation
    /// (DESIGN.md §12) and no shard ever holds another's KV.
    fn reserve_kv(&self, state: &mut ShardedState, n_tokens: usize) -> Result<()> {
        for (s, backend) in self.shards.iter().enumerate() {
            let (l0, l1) = self.layer_range(s);
            backend.reserve_kv_range(&mut state.states[s], n_tokens, l0, l1)?;
        }
        Ok(())
    }

    /// Preemption swap-out across every shard's store; returns the
    /// total blocks demoted.
    fn swap_out_kv(&self, state: &mut ShardedState) -> Result<u64> {
        let mut demoted = 0u64;
        for (backend, st) in self.shards.iter().zip(state.states.iter_mut()) {
            demoted += backend.swap_out_kv(st)?;
        }
        Ok(demoted)
    }

    /// Prefix sharing is disabled under sharding (module docs): a bind
    /// would have to install blocks into every layer's table, but each
    /// shard owns only its own layers. Always a miss — the sequence
    /// prefills its whole prompt, so tokens are unchanged
    /// (invariants 11 ∧ 12) and only the traffic win is forgone.
    fn bind_prefix_kv(&self, _state: &mut ShardedState, _prompt: &[i32]) -> Result<usize> {
        Ok(0)
    }

    /// No-op twin of [`Self::bind_prefix_kv`]: nothing registers, so
    /// nothing can ever bind.
    fn register_prefix_kv(&self, _state: &mut ShardedState, _prompt: &[i32]) -> Result<()> {
        Ok(())
    }
}

impl ServeTuning for ShardedBackend {
    fn set_threads(&self, threads: usize) {
        for s in &self.shards {
            s.set_threads(threads);
        }
    }

    /// Fan the kernel-path selection out to every shard (the
    /// tensor-parallel head follows shard 0's path). Bit-identical on
    /// every path at every shard count — DESIGN.md §17 × invariant 12.
    fn set_kernel_path(&self, path: KernelPath) {
        for s in &self.shards {
            s.set_kernel_path(path);
        }
    }

    /// Bind the tenant's adapter on every shard (each shard executes
    /// its own layers' adapter sites, so each needs the binding; every
    /// registry accounts the bind identically).
    fn bind_adapter(&self, state: &mut ShardedState, adapter: Option<u32>) -> Result<()> {
        for (backend, st) in self.shards.iter().zip(state.states.iter_mut()) {
            backend.bind_adapter(st, adapter)?;
        }
        Ok(())
    }

    /// Merged adapter accounting: residency counters (binds, cold
    /// loads, streamed bytes/energy) come from shard 0 — every shard
    /// binds identically, so shard 0's counts equal the unsharded
    /// run's; execution counters (MACs, rows) sum across shards —
    /// each shard executed only its own layers' sites. The merged view
    /// is therefore bit-identical to unsharded serving.
    fn lora_stats(&self) -> Option<LoraServeStats> {
        let mut total = self.shards[0].lora_stats()?;
        for s in &self.shards[1..] {
            let st = s.lora_stats()?;
            total.adapter_macs += st.adapter_macs;
            total.base_macs += st.base_macs;
            total.adapter_rows += st.adapter_rows;
        }
        Some(total)
    }
}

impl InferenceBackend for ShardedBackend {
    type State = ShardedState;
    /// Hidden activations flow between partition stages exactly as on
    /// a single [`HostBackend`] — the pipeline is sharded, not the
    /// per-token dataflow.
    type Hidden = Vec<Vec<f32>>;

    fn model(&self) -> &ModelConfig {
        self.shards[0].model()
    }

    fn prefill_len(&self) -> usize {
        self.model().max_seq
    }

    fn n_shards(&self) -> usize {
        self.shards.len()
    }

    fn new_state(&self) -> Result<ShardedState> {
        let states = self
            .shards
            .iter()
            .map(|s| s.new_state())
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardedState {
            states,
            pos: 0,
            prompt_len: 0,
        })
    }

    /// Embedding is a table lookup replicated on every shard; shard 0
    /// performs it (no events, no KV — owner is arbitrary).
    fn embed_prompt(&self, prompt: &[i32]) -> Result<Vec<Vec<f32>>> {
        self.shards[0].embed_prompt(prompt)
    }

    fn embed_token(&self, token: i32) -> Result<Vec<Vec<f32>>> {
        self.shards[0].embed_token(token)
    }

    /// Route the stage to the shard owning `part`; it appends the
    /// partition's KV rows into its own store via its own slice of the
    /// sequence state.
    fn run_partition_prefill(
        &self,
        part: usize,
        h: &Vec<Vec<f32>>,
        state: &mut ShardedState,
    ) -> Result<Vec<Vec<f32>>> {
        let s = self.parts.owner(part);
        self.shards[s].run_partition_prefill(part, h, &mut state.states[s])
    }

    fn run_partition_decode(
        &self,
        part: usize,
        h: &Vec<Vec<f32>>,
        pos: usize,
        state: &mut ShardedState,
    ) -> Result<Vec<Vec<f32>>> {
        let s = self.parts.owner(part);
        self.shards[s].run_partition_decode(part, h, pos, &mut state.states[s])
    }

    /// Fused batched decode under sharding: the whole batch routes to
    /// the shard owning `part` (each slot contributing its per-shard
    /// state slice), so the owning shard runs its one-GEMM-per-site
    /// fused stage exactly as an unsharded backend would — invariant
    /// 12 composes with the fusion invariant (DESIGN.md §17).
    fn run_partition_decode_batch(
        &self,
        part: usize,
        hs: Vec<Vec<Vec<f32>>>,
        entries: &mut [DecodeEntry<'_, ShardedState>],
    ) -> Vec<Result<Vec<Vec<f32>>>> {
        let s = self.parts.owner(part);
        let mut inner: Vec<DecodeEntry<'_, HostState>> = entries
            .iter_mut()
            .map(|e| DecodeEntry {
                state: &mut e.state.states[s],
                pos: e.pos,
            })
            .collect();
        self.shards[s].run_partition_decode_batch(part, hs, &mut inner)
    }

    /// Tensor-parallel head on the fast path; event mode delegates the
    /// whole projection to shard 0 so its event tally lands in exactly
    /// one shard (the merged counters then sum to unsharded).
    fn head_at(&self, h: &Vec<Vec<f32>>, idx: usize) -> Result<Logits> {
        if self.event_mode {
            return self.shards[0].head_at(h, idx);
        }
        let row = h
            .get(idx)
            .ok_or_else(|| anyhow!("head index {idx} past {} hidden rows", h.len()))?;
        Ok(self.tp_head(row))
    }

    fn head_decode_logits(&self, h: &Vec<Vec<f32>>) -> Result<Logits> {
        if self.event_mode {
            return self.shards[0].head_decode_logits(h);
        }
        let row = h.last().ok_or_else(|| anyhow!("empty decode hidden"))?;
        Ok(self.tp_head(row))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitnet::{ref_gemm, ref_gemv};
    use crate::util::rng::Rng;

    fn micro() -> ModelConfig {
        ModelConfig {
            name: "shard-micro".into(),
            n_layers: 2,
            d_model: 32,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 64,
            vocab_size: 64,
            max_seq: 32,
            n_partitions: 2,
            act_bits: 8,
        }
    }

    #[test]
    fn near_even_plans_cover_contiguously_first_heavy() {
        for (n, k) in [(10, 3), (6, 6), (7, 2), (5, 8), (0, 3), (1, 1), (23, 5)] {
            let plan = ShardPlan::near_even(n, k);
            assert_eq!(plan.n_shards(), k.max(1));
            let mut expect = 0usize;
            for s in 0..plan.n_shards() {
                let (lo, hi) = plan.range(s);
                assert_eq!(lo, expect, "gap before shard {s} at ({n}, {k})");
                assert!(hi >= lo);
                expect = hi;
            }
            assert_eq!(expect, n, "plan does not cover ({n}, {k})");
            // first-heavy near-even: sizes differ by at most one and
            // never increase along the shard order
            let sizes: Vec<usize> = plan.ranges().iter().map(|&(lo, hi)| hi - lo).collect();
            let (min, max) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
            assert!(max - min <= 1);
            assert!(sizes.windows(2).all(|w| w[0] >= w[1]));
            // every covered item has exactly one owner
            for item in 0..n {
                let s = plan.owner(item);
                let (lo, hi) = plan.range(s);
                assert!((lo..hi).contains(&item));
            }
        }
    }

    #[test]
    fn sharded_gemv_and_gemm_match_the_golden_reference() {
        let mut rng = Rng::new(0x51A2);
        let w = TernaryMatrix::random(37, 23, 0.3, &mut rng);
        let xs: Vec<Vec<i32>> = (0..3)
            .map(|_| (0..37).map(|_| (rng.next_u64() % 17) as i32 - 8).collect())
            .collect();
        let pool = Pool::new(1);
        let want_v = ref_gemv(&xs[0], &w);
        let want_m = ref_gemm(&xs, &w);
        // uneven splits, 1-column shards, and more shards than columns
        for n_shards in [1usize, 2, 3, 5, 23, 40] {
            assert_eq!(
                sharded_gemv(&xs[0], &w, n_shards, &pool),
                want_v,
                "gemv partial merge diverged at {n_shards} shards"
            );
            assert_eq!(
                sharded_gemm(&xs, &w, n_shards, &pool),
                want_m,
                "gemm partial merge diverged at {n_shards} shards"
            );
        }
    }

    #[test]
    fn sharded_generation_matches_unsharded_bit_exactly() {
        // invariant 12 at the backend level: the provided greedy driver
        // through partition routing + the tensor-parallel head must
        // reproduce the single-backend tokens exactly
        let prompt = [7, 3, 11, 40];
        let want = HostBackend::new(micro(), 77).unwrap().generate_greedy(&prompt, 8).unwrap();
        for n_shards in [1usize, 2] {
            let b = ShardedBackend::new(micro(), 77, n_shards).unwrap();
            assert_eq!(b.n_shards(), n_shards);
            assert_eq!(
                b.generate_greedy(&prompt, 8).unwrap(),
                want,
                "tokens diverged at {n_shards} shards"
            );
        }
    }

    #[test]
    fn sharded_kv_stats_sum_to_the_unsharded_totals() {
        let prompt = [4, 8, 15, 16];
        let solo = HostBackend::new(micro(), 21).unwrap();
        solo.generate_greedy(&prompt, 6).unwrap();
        let want = solo.kv_stats().unwrap();
        let b = ShardedBackend::new(micro(), 21, 2).unwrap();
        b.generate_greedy(&prompt, 6).unwrap();
        let per_shard = b.shard_kv_stats();
        assert_eq!(per_shard.len(), 2);
        assert!(per_shard.iter().all(|s| s.accesses.total_accesses() > 0));
        let merged = b.kv_stats().unwrap();
        // combined (placement-invariant) counters sum exactly
        assert_eq!(
            merged.accesses.ondie_writes + merged.accesses.external_writes,
            want.accesses.ondie_writes + want.accesses.external_writes
        );
        assert_eq!(
            merged.accesses.ondie_reads + merged.accesses.external_reads,
            want.accesses.ondie_reads + want.accesses.external_reads
        );
        assert_eq!(merged.retention_failures, 0);
        assert_eq!(merged.quant_bits, want.quant_bits);
        // the merged view is the field-wise sum of the per-shard view
        let sum: u64 = per_shard.iter().map(|s| s.accesses.total_accesses()).sum();
        assert_eq!(merged.accesses.total_accesses(), sum);
    }

    #[test]
    fn from_shards_validates_the_fleet() {
        assert!(ShardedBackend::from_shards(vec![]).is_err(), "empty fleet");
        // mismatched weight seeds would silently diverge mid-pipeline
        let a = HostBackend::new(micro(), 1).unwrap();
        let b = HostBackend::new(micro(), 2).unwrap();
        assert!(ShardedBackend::from_shards(vec![a, b]).is_err());
        // more shards than partitions leaves stage-less shards
        let fleet: Vec<HostBackend> =
            (0..3).map(|_| HostBackend::new(micro(), 1).unwrap()).collect();
        assert!(ShardedBackend::from_shards(fleet).is_err());
        // the convenience constructor clamps instead
        let c = ShardedBackend::new(micro(), 1, 9).unwrap();
        assert_eq!(c.n_shards(), micro().n_partitions);
        let plan = c.partition_plan();
        assert_eq!(plan.n_shards(), 2);
        assert_eq!((plan.range(0), plan.range(1)), ((0, 1), (1, 2)));
    }

    #[test]
    fn sharded_adapter_serving_matches_unsharded() {
        use crate::lora::{AdapterRegistry, LoraConfig};
        let reg =
            |seed| AdapterRegistry::fabricate(&micro(), &LoraConfig::paper(), 2, seed).unwrap();
        let solo = HostBackend::with_adapters(micro(), 11, reg(99)).unwrap();
        let prompt = [3, 14, 15, 9];
        let want = solo.generate_greedy_bound(&prompt, 8, Some(1)).unwrap();
        let fleet: Vec<HostBackend> = (0..2)
            .map(|_| HostBackend::with_adapters(micro(), 11, reg(99)).unwrap())
            .collect::<Vec<_>>();
        let b = ShardedBackend::from_shards(fleet).unwrap();
        assert_eq!(b.generate_greedy_bound(&prompt, 8, Some(1)).unwrap(), want);
        // residency from shard 0, execution summed: equal to unsharded
        let (s_solo, s_shard) = (solo.lora_stats().unwrap(), b.lora_stats().unwrap());
        assert_eq!(s_shard.binds, s_solo.binds);
        assert_eq!(s_shard.cold_loads, s_solo.cold_loads);
        assert_eq!(s_shard.bytes_streamed, s_solo.bytes_streamed);
        assert_eq!(s_shard.adapter_macs, s_solo.adapter_macs);
        assert_eq!(s_shard.base_macs, s_solo.base_macs);
        assert_eq!(s_shard.adapter_rows, s_solo.adapter_rows);
        let per = b.shard_lora_stats().unwrap();
        assert_eq!(per.iter().map(|s| s.adapter_macs).sum::<u64>(), s_solo.adapter_macs);
    }

    #[test]
    fn prefix_binds_always_miss_under_sharding() {
        let b = ShardedBackend::new(micro(), 23, 2).unwrap();
        let prompt = [9, 4, 2, 30, 7, 11, 3, 8, 1];
        let mut donor = b.new_state().unwrap();
        let mut h = b.embed_prompt(&prompt).unwrap();
        for part in 0..b.n_partitions() {
            h = b.run_partition_prefill(part, &h, &mut donor).unwrap();
        }
        b.register_prefix_kv(&mut donor, &prompt).unwrap();
        let mut binder = b.new_state().unwrap();
        assert_eq!(b.bind_prefix_kv(&mut binder, &prompt).unwrap(), 0);
        assert_eq!(b.kv_stats().unwrap().prefix_hits, 0);
    }

    #[test]
    fn backend_is_sync_and_states_are_send() {
        // the serving loop's parallel rounds need exactly these bounds
        fn takes_sync<T: Sync + Send>() {}
        fn takes_send<T: Send>() {}
        takes_sync::<ShardedBackend>();
        takes_send::<ShardedState>();
    }
}
