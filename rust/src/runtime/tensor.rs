//! Minimal host tensor + Literal conversion helpers for the runtime.

use anyhow::Result;

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    /// Shape (row-major).
    pub dims: Vec<usize>,
    /// Flat element storage.
    pub data: Vec<f32>,
}

impl TensorF32 {
    /// Tensor from shape + data (length-checked).
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "shape/data mismatch");
        TensorF32 { dims, data }
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        TensorF32 {
            dims,
            data: vec![0.0; n],
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Convert to an xla literal of the same shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Convert from an xla literal, imposing `dims`.
    pub fn from_literal(lit: &xla::Literal, dims: Vec<usize>) -> Result<Self> {
        let data = lit.to_vec::<f32>()?;
        anyhow::ensure!(
            data.len() == dims.iter().product::<usize>(),
            "literal has {} elements, expected shape {:?}",
            data.len(),
            dims
        );
        Ok(TensorF32 { dims, data })
    }

    /// Index of the maximum element (greedy sampling over logits) —
    /// shares the sampling policy with [`Logits`](super::Logits).
    pub fn argmax(&self) -> usize {
        super::backend::argmax_f32(&self.data)
    }

    /// Top-k indices by value, descending (same shared policy).
    pub fn top_k(&self, k: usize) -> Vec<usize> {
        super::backend::top_k_f32(&self.data, k)
    }
}

/// i32 token vector → Literal of shape `[n]`.
pub fn tokens_to_literal(tokens: &[i32]) -> Result<xla::Literal> {
    let dims = [tokens.len() as i64];
    Ok(xla::Literal::vec1(tokens).reshape(&dims)?)
}

/// Scalar i32 literal (positions / indices).
pub fn i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_topk() {
        let t = TensorF32::new(vec![5], vec![0.1, 3.0, -1.0, 3.5, 2.0]);
        assert_eq!(t.argmax(), 3);
        assert_eq!(t.top_k(3), vec![3, 1, 4]);
    }

    #[test]
    fn zeros_shape() {
        let t = TensorF32::zeros(vec![2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn shape_mismatch_panics() {
        TensorF32::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn literal_roundtrip() {
        let t = TensorF32::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = TensorF32::from_literal(&lit, vec![2, 2]).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn token_literal_roundtrip() {
        let lit = tokens_to_literal(&[1, 2, 3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }
}
