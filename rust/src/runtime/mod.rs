//! PJRT runtime: loads the AOT HLO artifacts (the "mask set") once at
//! startup and executes them from the serving hot path. Python is never
//! involved at runtime — the weights live inside the compiled
//! executables as constants, which is the CiROM deployment model.

mod manifest;
#[cfg(feature = "pjrt")]
mod model_exec;
#[cfg(feature = "pjrt")]
mod tensor;

pub use manifest::{ArtifactInfo, Manifest};
#[cfg(feature = "pjrt")]
pub use model_exec::{DecodeState, ModelExecutor};
#[cfg(feature = "pjrt")]
pub use tensor::TensorF32;
