//! Runtime layer: the backend-agnostic serving contract and its two
//! implementations.
//!
//! [`InferenceBackend`] captures what the coordinator needs from a
//! compute engine (embed / per-partition prefill & decode over opaque
//! per-sequence KV state / LM head); DESIGN.md §9 documents the
//! contract. Implementations:
//!
//! * [`HostBackend`] (always built) — a BitNet-style partitioned
//!   transformer on the word-parallel bitplane kernels with f32
//!   attention, fabricated from a `ModelConfig` + seed; its KV lives
//!   in the tiered quantized `kvcache::KvStore`, and it can serve a
//!   multi-tenant `lora::AdapterRegistry` (per-sequence adapters bound
//!   via [`ServeTuning::bind_adapter`]). The whole serving stack
//!   runs offline on it under tier-1. Control-plane hooks live on the
//!   grouped [`KvControl`]/[`ServeTuning`] supertraits (DESIGN.md
//!   §17); fused batched decode rides
//!   [`InferenceBackend::run_partition_decode_batch`].
//! * [`ShardedBackend`] (always built) — N same-seed [`HostBackend`]
//!   shards behind the same contract (DESIGN.md §16):
//!   pipeline-parallel partition ownership over per-shard KV stores
//!   plus a tensor-parallel exact-i64 LM head, tokens bit-identical to
//!   `--shards 1` at any shard count (invariant 12).
//! * `ModelExecutor` (`pjrt` feature) — loads the AOT HLO artifacts
//!   (the "mask set") once at startup and executes them via the PJRT C
//!   API; weights live inside the compiled executables as constants,
//!   which is the CiROM deployment model. Python is never involved at
//!   runtime.
//!
//! Manifest handling is always available.

mod backend;
mod host;
mod manifest;
mod sharding;
#[cfg(feature = "pjrt")]
mod model_exec;
#[cfg(feature = "pjrt")]
mod tensor;

pub use backend::{
    argmax_f32, top_k_f32, DecodeEntry, InferenceBackend, KvControl, Logits, SequenceState,
    ServeTuning,
};
pub use host::{HostBackend, HostState};
pub use manifest::{ArtifactInfo, Manifest};
pub use sharding::{sharded_gemm, sharded_gemv, ShardPlan, ShardedBackend, ShardedState};
#[cfg(feature = "pjrt")]
pub use model_exec::{DecodeState, ModelExecutor};
#[cfg(feature = "pjrt")]
pub use tensor::TensorF32;
