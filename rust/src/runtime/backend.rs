//! Backend-agnostic inference API — the serving contract.
//!
//! The coordinator's serving loop (continuous batching + the partition
//! pipeline of paper §V-B) needs exactly this much from a compute
//! engine: embed a prompt or token, run one partition's prefill/decode
//! stage over per-sequence KV state, and project a hidden state through
//! the LM head. Everything else — what a tensor is, where the KV cache
//! lives, whether the MACs run inside AOT-compiled PJRT executables or
//! on the host bitplane kernels — is the backend's own business,
//! captured in the associated [`State`](InferenceBackend::State) and
//! [`Hidden`](InferenceBackend::Hidden) types.
//!
//! Two implementations ship in-tree (DESIGN.md §9):
//! * `ModelExecutor` (`pjrt` feature) — the compiled-artifact
//!   runtime, the CiROM deployment model.
//! * [`HostBackend`](super::HostBackend) (always built) — a small
//!   BitNet-style partitioned transformer on the word-parallel bitplane
//!   kernel engine, so the whole serving stack runs offline under
//!   tier-1.

use anyhow::Result;

use crate::bitnet::KernelPath;
use crate::config::{ModelConfig, ServeConfig};
use crate::kvcache::KvStoreStats;
use crate::lora::LoraServeStats;

/// Decode progress every backend's per-sequence KV state must expose.
/// `pos` is the number of positions already written (the next token's
/// KV lands there); `prompt_len` is fixed after prefill.
pub trait SequenceState {
    /// Positions already written (the next token's KV lands here).
    fn pos(&self) -> usize;
    /// Set the decode position.
    fn set_pos(&mut self, pos: usize);
    /// Prompt length fixed at prefill.
    fn prompt_len(&self) -> usize;
    /// Record the prompt length after prefill.
    fn set_prompt_len(&mut self, len: usize);
}

/// Index of the maximum element of `data` (greedy sampling). The one
/// implementation both `Logits` and the pjrt `TensorF32` share, so a
/// tie-break/NaN policy change can never diverge the two paths.
pub fn argmax_f32(data: &[f32]) -> usize {
    data.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Top-k indices of `data` by value, descending (shared like
/// [`argmax_f32`]).
pub fn top_k_f32(data: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..data.len()).collect();
    idx.sort_by(|&a, &b| data[b].partial_cmp(&data[a]).unwrap_or(std::cmp::Ordering::Equal));
    idx.truncate(k);
    idx
}

/// Next-token logits in host memory — the one tensor type the serving
/// layer itself needs to understand (for sampling), so it is a concrete
/// type rather than an associated one.
#[derive(Debug, Clone, PartialEq)]
pub struct Logits {
    /// One logit per vocabulary entry.
    pub data: Vec<f32>,
}

impl Logits {
    /// Wrap a raw logit vector.
    pub fn new(data: Vec<f32>) -> Self {
        Logits { data }
    }

    /// Vocabulary size.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when no logits are present.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Index of the maximum element (greedy sampling).
    pub fn argmax(&self) -> usize {
        argmax_f32(&self.data)
    }

    /// Top-k indices by value, descending.
    pub fn top_k(&self, k: usize) -> Vec<usize> {
        top_k_f32(&self.data, k)
    }
}

/// KV-store lifecycle control — the grouped surface for everything the
/// serving coordinator does to a backend's (optional) tiered KV store:
/// construction-time configuration, retention clocks, page
/// reservation, preemption swap-out, prefix sharing, and measured
/// stats (DESIGN.md §17). A required supertrait of
/// [`InferenceBackend`] with [`Seq`](Self::Seq) pinned to the
/// backend's `State`, so the former pile of ad-hoc hooks reads as one
/// cohesive contract; every method keeps its no-op/miss default, so
/// backends without a host-side store implement nothing beyond `Seq`.
pub trait KvControl {
    /// Per-sequence KV state this control surface mutates — always
    /// the same type as [`InferenceBackend::State`] (the supertrait
    /// bound enforces it).
    type Seq: SequenceState;

    /// Rebuild the backend's tiered KV store (if it has one) for a
    /// serving deployment: on-die capacity, early-token threshold,
    /// page size and quantization all come from the [`ServeConfig`].
    /// The server calls this once at construction, before any state
    /// exists. Backends with opaque device-side KV (the PJRT runtime)
    /// keep the no-op default.
    fn configure_kv(&self, _serve: &ServeConfig) -> Result<()> {
        Ok(())
    }

    /// Advance the KV store's DR-eDRAM retention clock to `now_s`
    /// (modeled hardware seconds). The serving loop calls this once
    /// per token round; a stalled loop then surfaces retention
    /// failures on the next KV read. No-op without a store.
    fn advance_kv_clock(&self, _now_s: f64) {}

    /// Advance one shard's DR-eDRAM retention clock independently
    /// (shard-local retention storms, DESIGN.md §13 under §16). The
    /// serving loop only calls this when
    /// [`InferenceBackend::n_shards`] > 1; single-shard backends
    /// default to the global clock.
    fn advance_kv_clock_shard(&self, _shard: usize, now_s: f64) {
        self.advance_kv_clock(now_s);
    }

    /// Pre-allocate KV pages for this sequence's next `n_tokens`
    /// positions across every layer, deciding their tier placement
    /// *now*. The serving loop calls this on the coordinator thread in
    /// slot order before each token round, so shared-capacity
    /// placement (and any eviction) is deterministic even when the
    /// round's partition stages then run on worker threads — KV-store
    /// *allocation* stays a coordinator-side mutation (DESIGN.md §12).
    /// Backends without a host-side store keep the no-op default;
    /// reserving never changes stored values or access counts.
    fn reserve_kv(&self, _state: &mut Self::Seq, _n_tokens: usize) -> Result<()> {
        Ok(())
    }

    /// Measured KV-tier statistics (accesses, evictions, retention
    /// health, energy), if this backend's KV lives in a
    /// [`crate::kvcache::KvStore`]. `None` for backends whose KV is
    /// opaque to the host.
    fn kv_stats(&self) -> Option<KvStoreStats> {
        None
    }

    /// Swap this sequence's KV out of the capacity-bounded on-die tier
    /// to external memory, freeing on-die pages for other sequences
    /// (preemption under memory pressure, DESIGN.md §13). Stored
    /// values must be unchanged — a preempted sequence resumes from
    /// the external tier with bit-identical KV, no recompute. Returns
    /// the number of blocks demoted; backends without a tiered
    /// host-side store keep the no-op default.
    fn swap_out_kv(&self, _state: &mut Self::Seq) -> Result<u64> {
        Ok(0)
    }

    /// Bind the longest shared KV prefix of `prompt` already published
    /// in this backend's store into a *fresh* sequence (content-hash
    /// full-block match, reference-counted — DESIGN.md §15). Returns
    /// how many prompt tokens were bound; the caller prefills only the
    /// unshared tail `prompt[bound..]`. Binding must never change
    /// values — only which pages a sequence's tables point at — and at
    /// most `prompt.len() - 1` tokens bind, so the sampled last prompt
    /// token is always recomputed. Backends without a host-side store
    /// keep the miss default.
    fn bind_prefix_kv(&self, _state: &mut Self::Seq, _prompt: &[i32]) -> Result<usize> {
        Ok(0)
    }

    /// Publish this sequence's full prompt-prefix blocks for reuse by
    /// later sequences with the same (adapter, prompt-prefix) content.
    /// Called by the coordinator in slot order after a prefill
    /// completes; first writer wins, so registration order — and hence
    /// sharing — is deterministic at any thread width. Backends
    /// without a host-side store keep the no-op default.
    fn register_prefix_kv(&self, _state: &mut Self::Seq, _prompt: &[i32]) -> Result<()> {
        Ok(())
    }
}

/// Execution tuning and tenant-adapter control — kernel thread width,
/// kernel path selection, LoRA adapter binds and stats (DESIGN.md
/// §17). Like [`KvControl`] (which it extends, sharing
/// [`Seq`](KvControl::Seq)), a required supertrait of
/// [`InferenceBackend`]. Tuning must never change tokens — only
/// throughput (DESIGN.md §12, §17).
pub trait ServeTuning: KvControl {
    /// Shard this backend's kernels across `threads` workers (0 keeps
    /// the current width). The server calls this once at construction
    /// with the deployment's resolved `ServeConfig::threads`; backends
    /// without host-side kernels keep the no-op default. Width must
    /// never change results — only speed (DESIGN.md §12).
    fn set_threads(&self, _threads: usize) {}

    /// Select the bitplane kernel path (`Auto`/`Scalar`/`BitSerial`)
    /// for every subsequent projection this backend runs. All paths
    /// are bit-identical to `ref_gemv` (DESIGN.md §17), so this — like
    /// [`Self::set_threads`] — changes throughput, never results.
    /// Backends without host-side kernels keep the no-op default.
    fn set_kernel_path(&self, _path: KernelPath) {}

    /// Bind a tenant's LoRA adapter (or `None` for the frozen base
    /// model) to a fresh sequence, *before* its prefill runs — the
    /// adapter shapes every projection the sequence executes, so a
    /// late bind would split its KV history across tasks. Task
    /// switching is reload-free by construction: nothing in this call
    /// (or anywhere in the API) can move a base weight. The default
    /// accepts only `None`; backends with an
    /// [`crate::lora::AdapterRegistry`] override it.
    fn bind_adapter(&self, _state: &mut Self::Seq, adapter: Option<u32>) -> Result<()> {
        anyhow::ensure!(
            adapter.is_none(),
            "this backend serves no LoRA adapters (requested adapter {})",
            adapter.unwrap_or_default()
        );
        Ok(())
    }

    /// Measured adapter-serving statistics (binds, cold-load
    /// streaming, executed adapter/base MACs), if this backend serves
    /// an [`crate::lora::AdapterRegistry`]. `None` otherwise.
    fn lora_stats(&self) -> Option<LoraServeStats> {
        None
    }
}

/// One decoding sequence's slice of a fused decode round: its mutable
/// KV state and the absolute position its next token writes at. The
/// batched hook ([`InferenceBackend::run_partition_decode_batch`])
/// takes these alongside the per-slot hidden activations so a backend
/// can run one weight-amortized GEMM per projection site while still
/// appending/attending each sequence's KV independently.
pub struct DecodeEntry<'a, S> {
    /// The sequence's KV state (mutated: one position appended).
    pub state: &'a mut S,
    /// Absolute position this token writes at (`state.pos()` at round
    /// start).
    pub pos: usize,
}

/// The execution contract the serving coordinator schedules onto.
///
/// A backend is a *loaded model*: partitioned into
/// [`n_partitions`](Self::n_partitions) pipeline stages, able to run
/// one stage of one sequence's current token through itself, holding
/// all weights resident for its whole lifetime (the weight reload-free
/// premise — nothing in this API can move a weight). Control-plane
/// hooks live on the grouped supertraits [`KvControl`] (KV lifecycle)
/// and [`ServeTuning`] (kernel/adapter tuning), both pinned to
/// `Seq = State`; import those traits to call them.
pub trait InferenceBackend: ServeTuning<Seq = <Self as InferenceBackend>::State> {
    /// Opaque per-sequence KV state. Backends choose their own tensor
    /// representation; the coordinator only tracks `pos`/`prompt_len`.
    type State: SequenceState;
    /// Opaque hidden activation flowing between pipeline stages.
    type Hidden;

    /// The architecture this backend executes.
    fn model(&self) -> &ModelConfig;

    /// Prompt-bucket capacity: the longest prompt `embed_prompt`
    /// accepts (PJRT executables have a fixed prefill shape; host
    /// backends typically allow up to `model().max_seq`).
    fn prefill_len(&self) -> usize;

    /// Pipeline stages the model is partitioned into.
    fn n_partitions(&self) -> usize {
        self.model().n_partitions
    }

    /// True when execution latency is wall-clock-meaningful (real
    /// accelerator or PJRT dispatch): the coordinator then honors
    /// request arrival times by sleeping. Offline backends return
    /// false and let the serving clock skip idle gaps.
    fn realtime(&self) -> bool {
        false
    }

    /// Number of model shards behind this backend (DESIGN.md §16).
    /// Single-instance backends report 1; the multi-shard
    /// [`ShardedBackend`](crate::runtime::ShardedBackend) reports its
    /// fleet size so the coordinator can drive per-shard retention
    /// clocks and shard-local fault injection. Shard count must never
    /// change tokens — invariant 12.
    fn n_shards(&self) -> usize {
        1
    }

    /// Fresh (zeroed) per-sequence KV state.
    fn new_state(&self) -> Result<Self::State>;

    /// Embed a prompt (1..=`prefill_len` tokens) into the pipeline's
    /// input activation.
    fn embed_prompt(&self, prompt: &[i32]) -> Result<Self::Hidden>;

    /// Embed a single decode token.
    fn embed_token(&self, token: i32) -> Result<Self::Hidden>;

    /// One partition's prefill stage: consumes the hidden activation,
    /// writes the partition's KV rows for every prompt position.
    fn run_partition_prefill(
        &self,
        part: usize,
        h: &Self::Hidden,
        state: &mut Self::State,
    ) -> Result<Self::Hidden>;

    /// One partition's decode stage at absolute position `pos`: writes
    /// the partition's KV row at `pos`, attends over `0..=pos`.
    fn run_partition_decode(
        &self,
        part: usize,
        h: &Self::Hidden,
        pos: usize,
        state: &mut Self::State,
    ) -> Result<Self::Hidden>;

    /// One partition's decode stage for a whole batch of sequences at
    /// once — the fused-decode hook (DESIGN.md §17). `hs[i]` is
    /// sequence `entries[i]`'s hidden activation; the result vector is
    /// parallel to the inputs, with per-slot errors captured in place
    /// (one sequence's retention failure must not poison the rest —
    /// the caller drops failed slots from subsequent partitions).
    ///
    /// The default runs the per-slot [`Self::run_partition_decode`]
    /// loop, so every backend is correct out of the box; backends with
    /// host-side bitplane kernels override it to run **one GEMM per
    /// projection site** across the batch (weight words decoded once,
    /// reused for every row — the TOM/BitROM batch-amortization win).
    /// Fusion must be bit-identical to the per-slot loop: projections
    /// are exact integer ops and each row keeps its own quantization
    /// scale, so batching can never change tokens.
    fn run_partition_decode_batch(
        &self,
        part: usize,
        hs: Vec<Self::Hidden>,
        entries: &mut [DecodeEntry<'_, Self::State>],
    ) -> Vec<Result<Self::Hidden>> {
        assert_eq!(hs.len(), entries.len(), "fused decode batch mismatch");
        hs.into_iter()
            .zip(entries.iter_mut())
            .map(|(h, e)| self.run_partition_decode(part, &h, e.pos, e.state))
            .collect()
    }

    /// LM head over prefill hidden states at prompt row `idx`.
    fn head_at(&self, h: &Self::Hidden, idx: usize) -> Result<Logits>;

    /// LM head over a decode hidden state.
    fn head_decode_logits(&self, h: &Self::Hidden) -> Result<Logits>;

    // ---- provided drivers (single-stream paths built on the hooks) ----

    /// Full prefill: the prompt through every partition in order;
    /// returns (state, last-token logits).
    fn prefill(&self, prompt: &[i32]) -> Result<(Self::State, Logits)> {
        self.prefill_bound(prompt, None)
    }

    /// [`Self::prefill`] with a tenant adapter bound to the fresh
    /// sequence first (the single-stream twin of what the serving
    /// loop does per admitted request).
    fn prefill_bound(&self, prompt: &[i32], adapter: Option<u32>) -> Result<(Self::State, Logits)> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let mut state = self.new_state()?;
        self.bind_adapter(&mut state, adapter)?;
        let mut h = self.embed_prompt(prompt)?;
        for part in 0..self.n_partitions() {
            h = self.run_partition_prefill(part, &h, &mut state)?;
        }
        let logits = self.head_at(&h, prompt.len() - 1)?;
        state.set_pos(prompt.len());
        state.set_prompt_len(prompt.len());
        Ok((state, logits))
    }

    /// One full decode step for `token` (written at `state.pos()`);
    /// returns next-token logits.
    fn decode_step(&self, state: &mut Self::State, token: i32) -> Result<Logits> {
        let max_seq = self.model().max_seq;
        anyhow::ensure!(state.pos() < max_seq, "sequence exceeds max_seq {max_seq}");
        let mut h = self.embed_token(token)?;
        let pos = state.pos();
        for part in 0..self.n_partitions() {
            h = self.run_partition_decode(part, &h, pos, state)?;
        }
        state.set_pos(pos + 1);
        self.head_decode_logits(&h)
    }

    /// Greedy generation through the partitioned path (prefill + decode
    /// steps; always produces at least the prefill's first token).
    fn generate_greedy(&self, prompt: &[i32], n_new: usize) -> Result<Vec<i32>> {
        self.generate_greedy_bound(prompt, n_new, None)
    }

    /// [`Self::generate_greedy`] under a tenant adapter — the whole
    /// sequence (prefill and every decode step) runs with the
    /// adapter's low-rank deltas applied.
    fn generate_greedy_bound(
        &self,
        prompt: &[i32],
        n_new: usize,
        adapter: Option<u32>,
    ) -> Result<Vec<i32>> {
        let (mut state, logits) = self.prefill_bound(prompt, adapter)?;
        let mut out = Vec::with_capacity(n_new.max(1));
        let mut tok = logits.argmax() as i32;
        out.push(tok);
        for _ in 1..n_new {
            let logits = self.decode_step(&mut state, tok)?;
            tok = logits.argmax() as i32;
            out.push(tok);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logits_argmax_and_topk() {
        let l = Logits::new(vec![0.1, 3.0, -1.0, 3.5, 2.0]);
        assert_eq!(l.argmax(), 3);
        assert_eq!(l.top_k(3), vec![3, 1, 4]);
        assert_eq!(l.len(), 5);
        assert!(!l.is_empty());
    }

    /// Minimal mock backend: hidden = running token sum, logits put the
    /// mass on `sum % vocab`. Exercises the provided drivers and the
    /// pos/prompt_len bookkeeping without any tensor machinery.
    struct MockState {
        pos: usize,
        prompt_len: usize,
        writes: Vec<usize>,
    }

    impl SequenceState for MockState {
        fn pos(&self) -> usize {
            self.pos
        }
        fn set_pos(&mut self, pos: usize) {
            self.pos = pos;
        }
        fn prompt_len(&self) -> usize {
            self.prompt_len
        }
        fn set_prompt_len(&mut self, len: usize) {
            self.prompt_len = len;
        }
    }

    struct MockBackend {
        model: ModelConfig,
    }

    impl MockBackend {
        fn new() -> Self {
            MockBackend {
                model: ModelConfig::sim_tiny(),
            }
        }
    }

    impl KvControl for MockBackend {
        type Seq = MockState;
    }

    impl ServeTuning for MockBackend {}

    impl InferenceBackend for MockBackend {
        type State = MockState;
        type Hidden = i64;

        fn model(&self) -> &ModelConfig {
            &self.model
        }

        fn prefill_len(&self) -> usize {
            self.model.max_seq
        }

        fn new_state(&self) -> Result<MockState> {
            Ok(MockState {
                pos: 0,
                prompt_len: 0,
                writes: Vec::new(),
            })
        }

        fn embed_prompt(&self, prompt: &[i32]) -> Result<i64> {
            Ok(prompt.iter().map(|&t| t as i64).sum())
        }

        fn embed_token(&self, token: i32) -> Result<i64> {
            Ok(token as i64)
        }

        fn run_partition_prefill(
            &self,
            part: usize,
            h: &i64,
            state: &mut MockState,
        ) -> Result<i64> {
            state.writes.push(part);
            Ok(h + 1)
        }

        fn run_partition_decode(
            &self,
            part: usize,
            h: &i64,
            pos: usize,
            state: &mut MockState,
        ) -> Result<i64> {
            state.writes.push(100 * (pos + 1) + part);
            Ok(h + 1)
        }

        fn head_at(&self, h: &i64, idx: usize) -> Result<Logits> {
            let mut data = vec![0.0f32; self.model.vocab_size];
            let hot = (*h as usize + idx) % self.model.vocab_size;
            data[hot] = 1.0;
            Ok(Logits::new(data))
        }

        fn head_decode_logits(&self, h: &i64) -> Result<Logits> {
            let mut data = vec![0.0f32; self.model.vocab_size];
            data[(*h as usize) % self.model.vocab_size] = 1.0;
            Ok(Logits::new(data))
        }
    }

    #[test]
    fn provided_prefill_sets_state_and_visits_all_partitions() {
        let b = MockBackend::new();
        let (state, logits) = b.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(state.pos, 3);
        assert_eq!(state.prompt_len, 3);
        assert_eq!(state.writes, (0..b.n_partitions()).collect::<Vec<_>>());
        // hidden 6 + 6 partitions + idx 2 → argmax 14
        assert_eq!(logits.argmax(), 14);
    }

    #[test]
    fn provided_decode_advances_pos_and_bounds_max_seq() {
        let b = MockBackend::new();
        let (mut state, _) = b.prefill(&[1, 2, 3]).unwrap();
        b.decode_step(&mut state, 5).unwrap();
        assert_eq!(state.pos, 4);
        state.pos = b.model.max_seq;
        assert!(b.decode_step(&mut state, 5).is_err());
    }

    #[test]
    fn generate_greedy_emits_requested_tokens() {
        let b = MockBackend::new();
        let out = b.generate_greedy(&[1, 2, 3], 4).unwrap();
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|&t| (t as usize) < b.model.vocab_size));
    }

    #[test]
    fn default_batched_decode_is_the_per_slot_loop() {
        // two sequences decoding in one round through the default
        // batched hook must be indistinguishable from two independent
        // per-slot calls: same hiddens, same KV writes, same order
        let b = MockBackend::new();
        let (mut s1, _) = b.prefill(&[1, 2]).unwrap();
        let (mut s2, _) = b.prefill(&[4]).unwrap();
        let (mut r1, _) = b.prefill(&[1, 2]).unwrap();
        let (mut r2, _) = b.prefill(&[4]).unwrap();

        // reference: per-slot loop
        let a1 = b.run_partition_decode(0, &7, s1.pos, &mut s1).unwrap();
        let a2 = b.run_partition_decode(0, &9, s2.pos, &mut s2).unwrap();

        // batched hook (default implementation)
        let p1 = r1.pos;
        let p2 = r2.pos;
        let mut entries = vec![
            DecodeEntry { state: &mut r1, pos: p1 },
            DecodeEntry { state: &mut r2, pos: p2 },
        ];
        let out = b.run_partition_decode_batch(0, vec![7, 9], &mut entries);
        assert_eq!(out.len(), 2);
        assert_eq!(*out[0].as_ref().unwrap(), a1);
        assert_eq!(*out[1].as_ref().unwrap(), a2);
        assert_eq!(r1.writes, s1.writes);
        assert_eq!(r2.writes, s2.writes);
    }

    #[test]
    fn default_bind_accepts_only_the_base_model() {
        // a backend without adapter support must reject Some(_) loudly
        // instead of silently serving the base model for a tenant
        let b = MockBackend::new();
        let mut state = b.new_state().unwrap();
        assert!(b.bind_adapter(&mut state, None).is_ok());
        assert!(b.bind_adapter(&mut state, Some(0)).is_err());
        assert!(b.prefill_bound(&[1, 2], Some(3)).is_err());
        assert!(b.generate_greedy_bound(&[1, 2], 4, Some(1)).is_err());
        // the bound drivers with None are exactly the plain drivers
        let plain = b.generate_greedy(&[1, 2, 3], 4).unwrap();
        let bound = b.generate_greedy_bound(&[1, 2, 3], 4, None).unwrap();
        assert_eq!(plain, bound);
        assert!(b.lora_stats().is_none());
        // no tiered host store: swapping out demotes nothing
        assert_eq!(b.swap_out_kv(&mut state).unwrap(), 0);
        // tuning no-ops on a backend without host kernels
        b.set_threads(4);
        b.set_kernel_path(KernelPath::Scalar);
    }
}
