//! Workload generation: edge-style request traces (paper §IV: "edge
//! applications and short-sequence tasks such as instruction execution
//! and question answering"), plus the NDJSON request wire format the
//! HTTP front door accepts — a generated trace exports to the exact
//! bytes a client would POST, and a captured wire log rebuilds into a
//! trace the offline twin can replay (DESIGN.md §14).

use anyhow::Context;

use crate::net::jsonframe::{DecodeMode, FrameDecoder};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Request id (stable across the trace).
    pub id: u64,
    /// Arrival time (s) relative to trace start.
    pub arrival_s: f64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generation budget.
    pub max_new_tokens: usize,
    /// Tenant LoRA adapter this request decodes under (`None` = the
    /// frozen base model). Bound per sequence before prefill via
    /// `runtime::ServeTuning::bind_adapter`.
    pub adapter_id: Option<u32>,
    /// Priority class (higher = more urgent; 0 = the default class).
    /// Orders admission within a tenant queue and shields the request
    /// from preemption — scheduling only, never tokens (DESIGN.md
    /// invariant 11).
    pub priority: u8,
}

impl Request {
    /// Serialize to the request wire object — the same shape a client
    /// POSTs to `/v1/completions`. `adapter_id` is omitted for
    /// base-model requests so their wire bytes are identical to a
    /// build without adapter support.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("arrival_s", Json::num(self.arrival_s)),
            (
                "prompt",
                Json::Arr(self.prompt.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("max_new_tokens", Json::num(self.max_new_tokens as f64)),
        ];
        if let Some(a) = self.adapter_id {
            fields.push(("adapter_id", Json::num(a as f64)));
        }
        if self.priority > 0 {
            fields.push(("priority", Json::num(self.priority as f64)));
        }
        Json::obj(fields)
    }

    /// Parse from the wire object. `prompt` and `max_new_tokens` are
    /// required; `id` and `arrival_s` default to 0 (the HTTP front
    /// door assigns ids to anonymous submissions before admission).
    pub fn from_json(j: &Json) -> anyhow::Result<Request> {
        let prompt = j
            .get("prompt")
            .and_then(Json::as_arr)
            .context("request needs a prompt token array")?
            .iter()
            .map(|t| {
                t.as_i64()
                    .map(|v| v as i32)
                    .context("prompt tokens must be numbers")
            })
            .collect::<anyhow::Result<Vec<i32>>>()?;
        Ok(Request {
            id: j.get("id").and_then(Json::as_i64).unwrap_or(0) as u64,
            arrival_s: j.get("arrival_s").and_then(Json::as_f64).unwrap_or(0.0),
            prompt,
            max_new_tokens: j
                .get("max_new_tokens")
                .and_then(Json::as_usize)
                .context("request needs max_new_tokens")?,
            adapter_id: j.get("adapter_id").and_then(Json::as_i64).map(|v| v as u32),
            priority: j
                .get("priority")
                .and_then(Json::as_i64)
                .unwrap_or(0)
                .clamp(0, 255) as u8,
        })
    }
}

/// Serialize a trace as NDJSON: one request wire object per line, in
/// trace order — byte-for-byte what a replay client streams at the
/// HTTP front door.
pub fn export_ndjson(reqs: &[Request]) -> String {
    let mut out = String::new();
    for r in reqs {
        out.push_str(&r.to_json().to_string_compact());
        out.push('\n');
    }
    out
}

/// Rebuild a trace from NDJSON text (the inverse of
/// [`export_ndjson`]; also accepts CRLF framing and values split
/// across lines, via the strict [`FrameDecoder`]).
pub fn import_ndjson(text: &str) -> anyhow::Result<Vec<Request>> {
    let mut dec = FrameDecoder::new(DecodeMode::Strict);
    let mut vals = dec.push(text.as_bytes())?;
    if let Some(last) = dec.finish()? {
        vals.push(last);
    }
    vals.iter().map(Request::from_json).collect()
}

/// Trace generator parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Requests to generate.
    pub n_requests: usize,
    /// Minimum prompt length.
    pub prompt_len_min: usize,
    /// Maximum prompt length.
    pub prompt_len_max: usize,
    /// Minimum generation budget.
    pub gen_len_min: usize,
    /// Maximum generation budget.
    pub gen_len_max: usize,
    /// Vocabulary to draw prompt tokens from.
    pub vocab_size: usize,
    /// Mean arrival rate (req/s); 0 = all arrive at t=0 (closed batch).
    pub arrival_rate: f64,
    /// Tenant adapters to spread requests across (uniform draw of
    /// `adapter_id` in `0..n_adapters`); 0 = no request carries an
    /// adapter, and the generated trace is byte-identical to one from
    /// a build without adapter support.
    pub n_adapters: usize,
    /// Burst probability: with probability `burst_p` a request's
    /// arrival collapses onto the previous request's arrival instant,
    /// producing admission bursts that stress shedding and
    /// pressure-gated admission (DESIGN.md §13). 0 disables bursts and
    /// keeps the trace byte-identical to one from a build without
    /// burst support.
    pub burst_p: f64,
    /// Shared system-prompt length: when > 0, every request's first
    /// `shared_prefix_len` prompt tokens are overwritten with one of
    /// [`TraceConfig::shared_prefixes`] fixed system prompts (chat
    /// workloads where many conversations open with the same
    /// instructions — the prefix-cache hit population). Must stay
    /// below `prompt_len_min` so every request keeps a private tail.
    /// 0 disables the knob and the trace is byte-identical to one from
    /// a build without prefix support (DESIGN.md invariant 7).
    pub shared_prefix_len: usize,
    /// Number of distinct shared system prompts to rotate across
    /// (only read when `shared_prefix_len > 0`; values below 1 are
    /// treated as 1).
    pub shared_prefixes: usize,
    /// Multi-turn probability: with probability `turn_p` a request is
    /// a follow-up turn — its prompt is the previous request's full
    /// prompt (truncated to fit `prompt_len_max`) with this request's
    /// drawn tokens appended as the new turn. 0 disables the knob with
    /// zero extra draws.
    pub turn_p: f64,
    /// Priority classes: when > 1, each request draws a uniform
    /// priority in `0..priority_classes` (higher = more urgent).
    /// 0 or 1 disables the knob with zero extra draws and every
    /// request stays in the default class 0.
    pub priority_classes: usize,
    /// Generator seed.
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 24,
            prompt_len_min: 8,
            prompt_len_max: 48,
            gen_len_min: 16,
            gen_len_max: 64,
            vocab_size: 256,
            arrival_rate: 0.0,
            n_adapters: 0,
            burst_p: 0.0,
            shared_prefix_len: 0,
            shared_prefixes: 1,
            turn_p: 0.0,
            priority_classes: 0,
            seed: 1,
        }
    }
}

/// Generate a deterministic trace.
pub fn generate(cfg: &TraceConfig) -> Vec<Request> {
    assert!(cfg.prompt_len_min >= 1 && cfg.prompt_len_min <= cfg.prompt_len_max);
    assert!(cfg.gen_len_min >= 1 && cfg.gen_len_min <= cfg.gen_len_max);
    assert!(
        cfg.shared_prefix_len < cfg.prompt_len_min.max(1),
        "shared_prefix_len must leave every request a private tail"
    );
    // shared system prompts come from a derived stream so enabling the
    // knob never perturbs the per-request draws below (invariant 7)
    let prefixes: Vec<Vec<i32>> = if cfg.shared_prefix_len > 0 {
        let mut prng = Rng::new(cfg.seed ^ 0x5e1f_9afe);
        (0..cfg.shared_prefixes.max(1))
            .map(|_| {
                (0..cfg.shared_prefix_len)
                    .map(|_| prng.usize(0, cfg.vocab_size - 1) as i32)
                    .collect()
            })
            .collect()
    } else {
        Vec::new()
    };
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    let mut prev_arrival = 0.0f64;
    let mut prev_prompt: Vec<i32> = Vec::new();
    (0..cfg.n_requests)
        .map(|i| {
            if cfg.arrival_rate > 0.0 {
                t += rng.exp(cfg.arrival_rate);
            }
            let plen = rng.usize(cfg.prompt_len_min, cfg.prompt_len_max);
            let mut req = Request {
                id: i as u64,
                arrival_s: t,
                prompt: (0..plen)
                    .map(|_| rng.usize(0, cfg.vocab_size - 1) as i32)
                    .collect(),
                max_new_tokens: rng.usize(cfg.gen_len_min, cfg.gen_len_max),
                // drawn last (and only when enabled) so traces with
                // n_adapters == 0 consume exactly the pre-adapter
                // random stream — adapter-disabled traces stay
                // byte-identical (DESIGN.md invariant 7)
                adapter_id: if cfg.n_adapters > 0 {
                    Some(rng.usize(0, cfg.n_adapters - 1) as u32)
                } else {
                    None
                },
                priority: 0,
            };
            // the burst draw comes after everything else, same pattern:
            // burst_p == 0 consumes exactly the pre-burst stream
            if cfg.burst_p > 0.0 && rng.bool(cfg.burst_p) && i > 0 {
                req.arrival_s = prev_arrival;
            }
            // prefix / turn / priority draws follow the same
            // conditional-last discipline: a disabled knob consumes
            // zero draws, so the pre-knob stream is untouched
            if cfg.shared_prefix_len > 0 {
                let p = &prefixes[rng.usize(0, prefixes.len() - 1)];
                req.prompt[..p.len()].copy_from_slice(p);
            }
            if cfg.turn_p > 0.0 && rng.bool(cfg.turn_p) && !prev_prompt.is_empty() {
                let keep = prev_prompt
                    .len()
                    .min(cfg.prompt_len_max - req.prompt.len());
                let mut turn = prev_prompt[..keep].to_vec();
                turn.extend_from_slice(&req.prompt);
                req.prompt = turn;
            }
            if cfg.priority_classes > 1 {
                req.priority = rng.usize(0, cfg.priority_classes - 1) as u8;
            }
            prev_arrival = req.arrival_s;
            prev_prompt.clone_from(&req.prompt);
            req
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = TraceConfig::default();
        assert_eq!(generate(&cfg), generate(&cfg));
        let other = TraceConfig {
            seed: 2,
            ..TraceConfig::default()
        };
        assert_ne!(generate(&cfg), generate(&other));
    }

    #[test]
    fn respects_bounds() {
        let cfg = TraceConfig {
            n_requests: 100,
            ..TraceConfig::default()
        };
        for r in generate(&cfg) {
            assert!((8..=48).contains(&r.prompt.len()));
            assert!((16..=64).contains(&r.max_new_tokens));
            assert!(r.prompt.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn closed_batch_arrives_at_zero() {
        let reqs = generate(&TraceConfig::default());
        assert!(reqs.iter().all(|r| r.arrival_s == 0.0));
    }

    #[test]
    fn no_adapters_means_no_adapter_ids() {
        assert!(generate(&TraceConfig::default()).iter().all(|r| r.adapter_id.is_none()));
    }

    #[test]
    fn adapter_ids_cover_the_tenant_range() {
        let cfg = TraceConfig {
            n_requests: 64,
            n_adapters: 3,
            ..TraceConfig::default()
        };
        let reqs = generate(&cfg);
        let mut seen = [false; 3];
        for r in &reqs {
            let id = r.adapter_id.expect("every request carries a tenant") as usize;
            assert!(id < 3);
            seen[id] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 draws must hit all 3 tenants");
    }

    #[test]
    fn adapter_draws_do_not_perturb_the_workload_shape() {
        // the adapter id is drawn after a request's other fields, so
        // request i's prompt/budget match the adapter-free trace up
        // through request i's own draws... request 0 is identical.
        let base = generate(&TraceConfig::default());
        let with = generate(&TraceConfig {
            n_adapters: 2,
            ..TraceConfig::default()
        });
        assert_eq!(base[0].prompt, with[0].prompt);
        assert_eq!(base[0].max_new_tokens, with[0].max_new_tokens);
    }

    #[test]
    fn burst_free_traces_match_the_pre_burst_stream() {
        // burst_p == 0 must not consume any draws: the whole trace is
        // byte-identical to one generated without burst support
        let cfg = TraceConfig {
            arrival_rate: 10.0,
            n_requests: 32,
            ..TraceConfig::default()
        };
        assert_eq!(cfg.burst_p, 0.0);
        let base = generate(&cfg);
        let explicit = generate(&TraceConfig { burst_p: 0.0, ..cfg });
        assert_eq!(base, explicit);
    }

    #[test]
    fn bursts_collapse_arrivals_onto_the_previous_request() {
        let cfg = TraceConfig {
            arrival_rate: 10.0,
            n_requests: 64,
            burst_p: 0.5,
            ..TraceConfig::default()
        };
        let reqs = generate(&cfg);
        let ties = reqs
            .windows(2)
            .filter(|w| w[1].arrival_s == w[0].arrival_s)
            .count();
        assert!(ties > 0, "p=0.5 over 64 requests must produce bursts");
        // arrivals stay non-decreasing: a burst reuses an instant, it
        // never time-travels
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
    }

    #[test]
    fn prefix_turn_priority_knobs_do_not_perturb_prior_draws() {
        // each new knob draws conditionally-last, so request i's
        // arrival / prompt shape / budget match the knob-free trace;
        // the shared prefix only overwrites the prompt head in place
        let base = generate(&TraceConfig::default());
        let with = generate(&TraceConfig {
            shared_prefix_len: 6,
            shared_prefixes: 2,
            turn_p: 0.0,
            priority_classes: 3,
            ..TraceConfig::default()
        });
        for (b, w) in base.iter().zip(&with) {
            assert_eq!(b.prompt.len(), w.prompt.len());
            assert_eq!(b.prompt[6..], w.prompt[6..], "tail stays private");
            assert_eq!(b.max_new_tokens, w.max_new_tokens);
            assert_eq!(b.arrival_s, w.arrival_s);
        }
    }

    #[test]
    fn shared_prefixes_stamp_a_common_prompt_head() {
        let cfg = TraceConfig {
            n_requests: 32,
            shared_prefix_len: 6,
            shared_prefixes: 2,
            ..TraceConfig::default()
        };
        let reqs = generate(&cfg);
        let mut heads: Vec<Vec<i32>> = Vec::new();
        for r in &reqs {
            let h = r.prompt[..6].to_vec();
            if !heads.contains(&h) {
                heads.push(h);
            }
        }
        assert_eq!(heads.len(), 2, "32 draws over 2 system prompts hit both");
        // determinism: the prefix pool is seed-derived
        assert_eq!(reqs, generate(&cfg));
    }

    #[test]
    fn multi_turn_prompts_extend_the_previous_conversation() {
        let cfg = TraceConfig {
            n_requests: 32,
            prompt_len_min: 4,
            prompt_len_max: 64,
            turn_p: 0.7,
            ..TraceConfig::default()
        };
        let base = generate(&TraceConfig { turn_p: 0.0, ..cfg.clone() });
        let with = generate(&cfg);
        let mut follow_ups = 0;
        for i in 0..with.len() {
            // the drawn tokens always survive as the newest turn
            assert!(with[i].prompt.ends_with(&base[i].prompt));
            assert!(with[i].prompt.len() <= cfg.prompt_len_max);
            if with[i].prompt.len() > base[i].prompt.len() {
                let keep = with[i].prompt.len() - base[i].prompt.len();
                assert_eq!(
                    with[i].prompt[..keep],
                    with[i - 1].prompt[..keep],
                    "a follow-up turn opens with its conversation so far"
                );
                follow_ups += 1;
            }
        }
        assert!(follow_ups > 0, "p=0.7 over 32 requests must produce turns");
    }

    #[test]
    fn priority_classes_cover_the_range() {
        let cfg = TraceConfig {
            n_requests: 64,
            priority_classes: 3,
            ..TraceConfig::default()
        };
        let reqs = generate(&cfg);
        let mut seen = [false; 3];
        for r in &reqs {
            assert!((r.priority as usize) < 3);
            seen[r.priority as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "64 draws must hit all 3 classes");
        // priority survives the wire round trip, omitted when 0
        let back = import_ndjson(&export_ndjson(&reqs)).unwrap();
        assert_eq!(back, reqs);
    }

    #[test]
    fn ndjson_round_trips_generated_traces() {
        // mixed tenants + Poisson arrivals: every field survives the
        // wire format, including the absent-vs-present adapter_id
        let cfg = TraceConfig {
            n_requests: 16,
            arrival_rate: 5.0,
            n_adapters: 2,
            ..TraceConfig::default()
        };
        let reqs = generate(&cfg);
        let wire = export_ndjson(&reqs);
        assert_eq!(wire.lines().count(), 16);
        assert!(!wire.contains('\u{0}'));
        let back = import_ndjson(&wire).unwrap();
        assert_eq!(back, reqs);

        // base-model, default-class requests leave adapter_id and
        // priority off the wire entirely
        let plain = generate(&TraceConfig::default());
        assert!(!export_ndjson(&plain).contains("adapter_id"));
        assert!(!export_ndjson(&plain).contains("priority"));
        assert_eq!(import_ndjson(&export_ndjson(&plain)).unwrap(), plain);
    }

    #[test]
    fn wire_parse_defaults_and_requirements() {
        let j = Json::parse(r#"{"prompt":[1,2,3],"max_new_tokens":4}"#).unwrap();
        let r = Request::from_json(&j).unwrap();
        assert_eq!(r.id, 0);
        assert_eq!(r.arrival_s, 0.0);
        assert_eq!(r.adapter_id, None);
        assert_eq!(r.priority, 0);
        assert_eq!(r.prompt, vec![1, 2, 3]);

        let no_prompt = Json::parse(r#"{"max_new_tokens":4}"#).unwrap();
        assert!(Request::from_json(&no_prompt).is_err());
        let no_budget = Json::parse(r#"{"prompt":[1]}"#).unwrap();
        assert!(Request::from_json(&no_budget).is_err());
        let bad_tok = Json::parse(r#"{"prompt":[1,"x"],"max_new_tokens":4}"#).unwrap();
        assert!(Request::from_json(&bad_tok).is_err());
    }

    #[test]
    fn import_rejects_malformed_wire_text() {
        assert!(import_ndjson("{\"prompt\":[1],").is_err(), "truncated value");
        assert!(import_ndjson("not json\n").is_err(), "garbage line");
        // CRLF framing is accepted
        let reqs = import_ndjson("{\"prompt\":[7],\"max_new_tokens\":2}\r\n").unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].prompt, vec![7]);
    }

    #[test]
    fn poisson_arrivals_increase() {
        let cfg = TraceConfig {
            arrival_rate: 10.0,
            n_requests: 50,
            ..TraceConfig::default()
        };
        let reqs = generate(&cfg);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
        let mean_gap = reqs.last().unwrap().arrival_s / 49.0;
        assert!((mean_gap - 0.1).abs() < 0.05, "mean gap {mean_gap}");
    }
}
