//! End-to-end PJRT decode benchmarks: per-partition latency, full
//! decode-step latency, single-stream tokens/s (EXPERIMENTS.md §Perf L3).
//!
//! Requires artifacts (`make artifacts`); prints a skip note otherwise.

use bitrom::runtime::{Manifest, ModelExecutor};
use bitrom::util::bench::bench_config;

fn main() -> anyhow::Result<()> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP bench_decode: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let exec = ModelExecutor::load(&dir)?;
    println!(
        "loaded {} executables in {:.2}s",
        exec.manifest.artifacts.len(),
        exec.load_time_s
    );
    let b = bench_config();

    // embed + head (the auxiliary-processor ops)
    let r = b.run("embed_decode_token", || exec.embed_token(42).unwrap());
    println!("{}", r.report());

    // one partition decode step
    let mut state = exec.new_state()?;
    let h = exec.embed_token(1)?;
    let r = b.run("partition_decode (1 layer)", || {
        exec.run_partition_decode(0, &h, 0, &mut state).unwrap()
    });
    println!("{}", r.report());

    // full decode step, partitioned path (8 PJRT dispatches per token —
    // the §Perf L3 *before* number)
    let (mut state, logits) = exec.prefill(&[1, 2, 3, 4])?;
    let mut tok = logits.argmax() as i32;
    let max_seq = exec.manifest.model.max_seq;
    let r = b.run("decode_step partitioned (8 dispatches)", || {
        if state.pos + 1 >= max_seq {
            // reset the sequence when the cache fills up mid-bench
            let (s2, l2) = exec.prefill(&[1, 2, 3, 4]).unwrap();
            state = s2;
            tok = l2.argmax() as i32;
        }
        let logits = exec.decode_step(&mut state, tok).unwrap();
        tok = logits.argmax() as i32;
        tok
    });
    println!("{}", r.report());
    let partitioned_ns = r.mean_ns;
    println!("  -> single-stream decode: {:.1} tokens/s", 1e9 / r.mean_ns);

    // fused fast path (1 PJRT dispatch per token — the *after* number)
    if exec.has_fused() {
        let (mut fstate, flogits) = exec.fused_prefill(&[1, 2, 3, 4])?;
        let mut ftok = flogits.argmax() as i32;
        let r = b.run("decode_step fused (1 dispatch)", || {
            if fstate.pos + 1 >= max_seq {
                let (s2, l2) = exec.fused_prefill(&[1, 2, 3, 4]).unwrap();
                fstate = s2;
                ftok = l2.argmax() as i32;
            }
            let logits = exec.fused_decode_step(&mut fstate, ftok).unwrap();
            ftok = logits.argmax() as i32;
            ftok
        });
        println!("{}", r.report());
        println!(
            "  -> single-stream decode: {:.1} tokens/s ({:.2}x vs partitioned)",
            1e9 / r.mean_ns,
            partitioned_ns / r.mean_ns
        );
    } else {
        println!("fused artifacts absent — rerun `make artifacts` for the fast path");
    }

    // prefill latency (64-token bucket)
    let prompt: Vec<i32> = (0..48).map(|i| (i * 3) % 250).collect();
    let r = b.run("prefill (48-token prompt, 64 bucket)", || {
        exec.prefill(&prompt).unwrap().1
    });
    println!("{}", r.report());
    Ok(())
}
