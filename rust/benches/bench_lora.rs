//! Multi-tenant LoRA serving cost, measured end-to-end: the same
//! workload through `Server<HostBackend>` with 0 vs N tenant adapters
//! (identical prompts/budgets — adapter ids are assigned post-hoc so
//! the two runs differ only in the deltas), plus the task-switch
//! traffic and the measured per-token adapter op overhead. The
//! adapter-serving point is also swept across 1/4 worker threads
//! (DESIGN.md §12) — adapter accounting merges per-op, so the measured
//! overhead and switch traffic must not move with the width. Emits
//! `BENCH_lora.json` at the repository root; its `gates` object feeds
//! the CI perf-regression gate (`ci/check_bench.py` vs
//! `BENCH_baseline/`).
//!
//!   cargo bench --bench bench_lora            # full trace
//!   BITROM_BENCH_QUICK=1 cargo bench --bench bench_lora
//!
//! Override the output path with BITROM_BENCH_OUT.

use bitrom::config::{ModelConfig, ServeConfig};
use bitrom::coordinator::Server;
use bitrom::lora::{AdapterRegistry, LoraConfig};
use bitrom::runtime::HostBackend;
use bitrom::trace::{generate, Request, TraceConfig};
use bitrom::util::bench::bench_out_path;
use bitrom::util::json::Json;

struct Point {
    adapters: usize,
    threads: usize,
    tokens_per_s: f64,
    tokens: u64,
    measured_overhead: f64,
    cold_loads: u64,
    bytes_streamed: u64,
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BITROM_BENCH_QUICK").is_ok();
    let (n_requests, gen_len) = if quick { (8, 12) } else { (24, 32) };
    let model = ModelConfig::sim_tiny();
    let lora = LoraConfig::paper();
    let base_trace: Vec<Request> = generate(&TraceConfig {
        n_requests,
        gen_len_min: gen_len.min(8),
        gen_len_max: gen_len,
        vocab_size: model.vocab_size,
        ..TraceConfig::default()
    });

    println!(
        "== bench_lora: Server<HostBackend> with tenant adapters, {n_requests} requests, \
         gen <= {gen_len} =="
    );
    let mut points = Vec::new();
    let mut base_tput = 0.0f64;
    let mut adapters_serial_tput = 0.0f64;
    let mut serial_overhead = 0.0f64;
    // (0 adapters, serial) is the baseline; the 4-adapter point is
    // swept across worker-thread widths — identical workload per run
    for (n_adapters, threads) in [(0usize, 1usize), (4, 1), (4, 2), (4, 4)] {
        let backend = if n_adapters > 0 {
            let reg = AdapterRegistry::fabricate(&model, &lora, n_adapters, 0xADA9)?;
            HostBackend::with_adapters(model.clone(), 0xB17, reg)?
        } else {
            HostBackend::new(model.clone(), 0xB17)?
        };
        let serve = ServeConfig {
            max_batches: 6,
            n_adapters,
            threads,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve)?;
        // identical workload; only the adapter binding differs
        let mut reqs = base_trace.clone();
        if n_adapters > 0 {
            for (i, r) in reqs.iter_mut().enumerate() {
                r.adapter_id = Some((i % n_adapters) as u32);
            }
        }
        let (done, metrics) = server.run_trace(reqs)?;
        assert_eq!(done.len(), n_requests, "every request must complete");
        let tput = metrics.tokens_per_s();
        if n_adapters == 0 {
            base_tput = tput;
        }
        let lora_stats = metrics.lora.unwrap_or_default();
        if n_adapters > 0 && threads == 1 {
            adapters_serial_tput = tput;
            serial_overhead = lora_stats.measured_op_overhead();
        }
        println!(
            "  {n_adapters} adapters @ {threads} thread(s): {:>8.1} tok/s  (x{:.2} vs base)  \
             measured op overhead {:.2}%  cold loads {}  streamed {} B",
            tput,
            tput / base_tput.max(1e-9),
            lora_stats.measured_op_overhead() * 100.0,
            lora_stats.cold_loads,
            lora_stats.bytes_streamed,
        );
        if n_adapters > 0 {
            assert!(lora_stats.binds as usize >= n_requests.min(n_adapters));
            // per-op merged accounting is thread-count-invariant
            assert!(
                (lora_stats.measured_op_overhead() - serial_overhead).abs() < 1e-12,
                "adapter accounting moved with thread width"
            );
        }
        points.push(Point {
            adapters: n_adapters,
            threads,
            tokens_per_s: tput,
            tokens: metrics.tokens_out,
            measured_overhead: lora_stats.measured_op_overhead(),
            cold_loads: lora_stats.cold_loads,
            bytes_streamed: lora_stats.bytes_streamed,
        });
    }

    let analytic = lora.op_overhead_vs_host_projections(&model);
    let adapter_bytes = lora.storage_bytes(&model);
    let reload_bytes = AdapterRegistry::full_reload_bytes_for(&model);
    println!(
        "analytic op overhead {:.2}% | adapter {} B vs full reload {} B per task switch",
        analytic * 100.0,
        adapter_bytes,
        reload_bytes,
    );

    let adapter_ratio = adapters_serial_tput / base_tput.max(1e-9);
    let threads_4v1 = points
        .iter()
        .find(|p| p.adapters > 0 && p.threads == 4)
        .map(|p| p.tokens_per_s / adapters_serial_tput.max(1e-9))
        .unwrap_or(0.0);
    println!(
        "adapter throughput ratio {adapter_ratio:.2} (serial) | \
         threads speedup {threads_4v1:.2}x (4 threads, 4 adapters)"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("bench_lora")),
        ("model", Json::str(model.name.clone())),
        ("quick", Json::Bool(quick)),
        ("requests", Json::num(n_requests as f64)),
        ("gen_len", Json::num(gen_len as f64)),
        ("analytic_overhead", Json::num(analytic)),
        ("adapter_bytes", Json::num(adapter_bytes as f64)),
        ("full_reload_bytes", Json::num(reload_bytes as f64)),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("adapters", Json::num(p.adapters as f64)),
                            ("threads", Json::num(p.threads as f64)),
                            ("tokens_per_s", Json::num(p.tokens_per_s)),
                            ("tokens", Json::num(p.tokens as f64)),
                            ("measured_overhead", Json::num(p.measured_overhead)),
                            ("cold_loads", Json::num(p.cold_loads as f64)),
                            ("bytes_streamed", Json::num(p.bytes_streamed as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gates",
            Json::obj(vec![
                ("adapter_throughput_ratio", Json::num(adapter_ratio)),
                ("lora_threads_speedup_4v1", Json::num(threads_4v1)),
            ]),
        ),
    ]);
    let path = bench_out_path("BENCH_lora.json");
    match std::fs::write(&path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("recorded {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    Ok(())
}
