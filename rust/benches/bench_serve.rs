//! Offline serving throughput, measured end-to-end through
//! `Server<HostBackend>` (batcher + pipeline + KV accounting, no
//! artifacts needed) along two axes:
//!
//! * **batches** — the same trace at 1/2/4/6 in-flight slots (the §V-B
//!   "pipeline keeps all partitions busy" claim), serial engine;
//! * **threads** — the same trace at the paper's 6 slots across
//!   1/2/4 worker threads (the parallel execution engine, DESIGN.md
//!   §12). Tokens are asserted bit-identical across widths before any
//!   number is recorded;
//! * **fused decode** — the same trace at 8 slots, per-slot decode
//!   GEMVs vs one batched partition walk per round (DESIGN.md §17):
//!   tokens asserted bit-identical before the throughput ratio is
//!   recorded as the `fused_decode_speedup` gate;
//! * **faults** — the same trace under certain periodic retention
//!   storms (DESIGN.md §13): tokens asserted bit-identical to the
//!   fault-free run, and the recovery throughput ratio recorded as the
//!   `fault_recovery_throughput_ratio` gate;
//! * **streaming** — the same trace through the live ingress plane
//!   (DESIGN.md §14) with every token framed through the real NDJSON
//!   event encoder into a black box (the bytes a loopback client would
//!   receive, minus socket noise): tokens asserted bit-identical to
//!   the offline run (invariant 10), and the throughput ratio recorded
//!   as the `streaming_overhead_ratio` gate;
//! * **prefix** — the shared-prefix serving ledger (DESIGN.md §15) at
//!   its fixed operating point: tokens asserted bit-identical to the
//!   private-KV twin (invariant 11), and the measured external-DRAM
//!   reduction recorded as the `prefix_hit_dram_reduction` gate, which
//!   must stay above the Fig 5(b) measured baseline;
//! * **shards** — the same trace split across 1/2/4 model shards
//!   (DESIGN.md §16): tokens asserted bit-identical at every shard
//!   count (invariant 12), tokens/s and per-shard KV-tier statistics
//!   recorded, and the 4-shard / 1-shard throughput ratio recorded as
//!   the `shard_scaling_ratio` gate.
//!
//! Emits `BENCH_serve.json` at the repository root; its `gates` object
//! (scale-free speedups) feeds the CI perf-regression gate
//! (`ci/check_bench.py` vs `BENCH_baseline/`).
//!
//!   cargo bench --bench bench_serve            # full trace
//!   BITROM_BENCH_QUICK=1 cargo bench --bench bench_serve
//!
//! Override the output path with BITROM_BENCH_OUT.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use bitrom::config::{ModelConfig, ServeConfig};
use bitrom::coordinator::{CompletedRequest, FailReason, FaultMetrics, Ingress, Server, TokenSink};
use bitrom::kvcache::KvStoreStats;
use bitrom::net::jsonframe::{EventEncoder, StreamFormat};
use bitrom::report::{prefix_serving_study, FIG5B_MEASURED_BASELINE};
use bitrom::runtime::{HostBackend, ShardedBackend};
use bitrom::trace::{generate, TraceConfig};
use bitrom::util::bench::bench_out_path;
use bitrom::util::json::Json;

struct Point {
    batches: usize,
    threads: usize,
    tokens_per_s: f64,
    tbt_p50_ms: f64,
    tbt_p95_ms: f64,
    tokens: u64,
}

fn run_point(
    model: &ModelConfig,
    trace_cfg: &TraceConfig,
    batches: usize,
    threads: usize,
    fused: bool,
) -> anyhow::Result<(Point, Vec<(u64, Vec<i32>)>)> {
    let backend = HostBackend::new(model.clone(), 0xB17)?;
    let serve = ServeConfig {
        max_batches: batches,
        threads,
        fused_decode: fused,
        ..ServeConfig::default()
    };
    let mut server = Server::new(backend, serve)?;
    let (done, mut metrics) = server.run_trace(generate(trace_cfg))?;
    assert_eq!(done.len(), trace_cfg.n_requests, "every request must complete");
    let kv = metrics.kv.as_ref().expect("host backend measures KV stats");
    assert_eq!(kv.retention_failures, 0);
    let mut tokens: Vec<(u64, Vec<i32>)> = done.into_iter().map(|r| (r.id, r.tokens)).collect();
    tokens.sort_by_key(|(id, _)| *id);
    Ok((
        Point {
            batches,
            threads,
            tokens_per_s: metrics.tokens_per_s(),
            tbt_p50_ms: metrics.tbt.pct(50.0) * 1e3,
            tbt_p95_ms: metrics.tbt.pct(95.0) * 1e3,
            tokens: metrics.tokens_out,
        },
        tokens,
    ))
}

/// The same trace split across `shards` model shards (DESIGN.md §16),
/// always through the [`ShardedBackend`] wrapper — the 1-shard point
/// pays the same wrapper overhead, so the `shard_scaling_ratio` gate
/// isolates the cost of partition routing + per-shard stores rather
/// than the wrapper itself.
fn run_shard_point(
    model: &ModelConfig,
    trace_cfg: &TraceConfig,
    shards: usize,
) -> anyhow::Result<(Point, Vec<(u64, Vec<i32>)>, Vec<KvStoreStats>)> {
    let backend = ShardedBackend::new(model.clone(), 0xB17, shards)?;
    let serve = ServeConfig {
        max_batches: 6,
        threads: 1,
        shards,
        // the historical per-slot engine, so shard_scaling_ratio keeps
        // measuring partition routing rather than decode fusion
        fused_decode: false,
        ..ServeConfig::default()
    };
    let mut server = Server::new(backend, serve)?;
    let (done, mut metrics) = server.run_trace(generate(trace_cfg))?;
    assert_eq!(done.len(), trace_cfg.n_requests, "every request must complete");
    let per_shard = server.backend().shard_kv_stats();
    let mut tokens: Vec<(u64, Vec<i32>)> = done.into_iter().map(|r| (r.id, r.tokens)).collect();
    tokens.sort_by_key(|(id, _)| *id);
    Ok((
        Point {
            batches: 6,
            threads: 1,
            tokens_per_s: metrics.tokens_per_s(),
            tbt_p50_ms: metrics.tbt.pct(50.0) * 1e3,
            tbt_p95_ms: metrics.tbt.pct(95.0) * 1e3,
            tokens: metrics.tokens_out,
        },
        tokens,
        per_shard,
    ))
}

/// The same trace under a deterministic retention-storm fault plan
/// (DESIGN.md §13): every expiry must be recovered bit-identically, so
/// the only observable cost is throughput — which the
/// `fault_recovery_throughput_ratio` gate tracks.
fn run_fault_point(
    model: &ModelConfig,
    trace_cfg: &TraceConfig,
) -> anyhow::Result<(Point, Vec<(u64, Vec<i32>)>, FaultMetrics)> {
    let backend = HostBackend::new(model.clone(), 0xB17)?;
    let serve = ServeConfig {
        max_batches: 6,
        threads: 1,
        fault_seed: 0xFA11,
        fault_storm_p: 1.0,
        fault_transient_p: 0.0,
        fault_clock_skip_s: 0.1,
        retry_max: 16,
        fused_decode: false,
        ..ServeConfig::default()
    };
    let mut server = Server::new(backend, serve)?;
    let (done, mut metrics) = server.run_trace(generate(trace_cfg))?;
    assert_eq!(done.len(), trace_cfg.n_requests, "the retry budget must cover every storm");
    let kv = metrics.kv.as_ref().expect("host backend measures KV stats");
    assert_eq!(kv.retention_failures, metrics.faults.retention_events);
    let mut tokens: Vec<(u64, Vec<i32>)> = done.into_iter().map(|r| (r.id, r.tokens)).collect();
    tokens.sort_by_key(|(id, _)| *id);
    Ok((
        Point {
            batches: 6,
            threads: 1,
            tokens_per_s: metrics.tokens_per_s(),
            tbt_p50_ms: metrics.tbt.pct(50.0) * 1e3,
            tbt_p95_ms: metrics.tbt.pct(95.0) * 1e3,
            tokens: metrics.tokens_out,
        },
        tokens,
        metrics.faults.clone(),
    ))
}

/// Socket-free streaming sink: every token is framed through the real
/// NDJSON event encoder — the exact bytes a loopback client would
/// receive — and black-boxed, so the measured cost is live admission +
/// per-token encoding without network noise.
struct EncodeSink {
    enc: EventEncoder,
    bytes: Arc<AtomicU64>,
    finished: Arc<AtomicUsize>,
}

impl TokenSink for EncodeSink {
    fn on_token(&mut self, id: u64, tok: i32) -> bool {
        let frame = self.enc.frame(&Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("token", Json::num(tok as f64)),
        ]));
        self.bytes
            .fetch_add(std::hint::black_box(frame.len()) as u64, Ordering::Relaxed);
        true
    }

    fn on_complete(&mut self, done: &CompletedRequest) {
        let frame = self.enc.frame(&Json::obj(vec![
            ("id", Json::num(done.id as f64)),
            ("done", Json::Bool(true)),
        ]));
        self.bytes
            .fetch_add(std::hint::black_box(frame.len()) as u64, Ordering::Relaxed);
        self.finished.fetch_add(1, Ordering::SeqCst);
    }

    fn on_shed(&mut self, _id: u64, _reason: FailReason) {
        self.finished.fetch_add(1, Ordering::SeqCst);
    }
}

/// The same trace through the live admission plane (`run_ingress`)
/// with encoding sinks: the streaming twin of the serial 6-batch run.
fn run_stream_point(
    model: &ModelConfig,
    trace_cfg: &TraceConfig,
) -> anyhow::Result<(Point, Vec<(u64, Vec<i32>)>, u64)> {
    let backend = HostBackend::new(model.clone(), 0xB17)?;
    let serve = ServeConfig {
        max_batches: 6,
        threads: 1,
        fused_decode: false,
        ..ServeConfig::default()
    };
    let max_prompt = serve.prefill_len;
    let mut server = Server::new(backend, serve)?;
    let n = trace_cfg.n_requests;
    let ingress = Arc::new(Ingress::new(n.max(1), 0.0, max_prompt));
    let bytes = Arc::new(AtomicU64::new(0));
    let finished = Arc::new(AtomicUsize::new(0));
    ingress.pause();
    for req in generate(trace_cfg) {
        let sink = EncodeSink {
            enc: EventEncoder::new(StreamFormat::Ndjson),
            bytes: bytes.clone(),
            finished: finished.clone(),
        };
        ingress
            .submit_at(req, Box::new(sink), 0.0)
            .map_err(|r| anyhow::anyhow!("stream submit: {r}"))?;
    }
    ingress.resume();
    let watcher_ingress = ingress.clone();
    let watcher_finished = finished.clone();
    let watcher = std::thread::spawn(move || {
        while watcher_finished.load(Ordering::SeqCst) < n {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        watcher_ingress.shutdown();
    });
    let (done, mut metrics) = server.run_ingress(ingress, None)?;
    watcher.join().expect("watcher thread");
    assert_eq!(done.len(), n, "every streamed request must complete");
    let mut tokens: Vec<(u64, Vec<i32>)> = done.into_iter().map(|r| (r.id, r.tokens)).collect();
    tokens.sort_by_key(|(id, _)| *id);
    Ok((
        Point {
            batches: 6,
            threads: 1,
            tokens_per_s: metrics.tokens_per_s(),
            tbt_p50_ms: metrics.tbt.pct(50.0) * 1e3,
            tbt_p95_ms: metrics.tbt.pct(95.0) * 1e3,
            tokens: metrics.tokens_out,
        },
        tokens,
        bytes.load(Ordering::Relaxed),
    ))
}

fn point_json(p: &Point, vs: f64) -> Json {
    Json::obj(vec![
        ("batches", Json::num(p.batches as f64)),
        ("threads", Json::num(p.threads as f64)),
        ("tokens_per_s", Json::num(p.tokens_per_s)),
        ("tbt_p50_ms", Json::num(p.tbt_p50_ms)),
        ("tbt_p95_ms", Json::num(p.tbt_p95_ms)),
        ("tokens", Json::num(p.tokens as f64)),
        ("speedup_vs_base", Json::num(vs)),
    ])
}

fn shard_kv_json(s: &KvStoreStats) -> Json {
    Json::obj(vec![
        ("ondie_reads", Json::num(s.accesses.ondie_reads as f64)),
        ("ondie_writes", Json::num(s.accesses.ondie_writes as f64)),
        ("external_reads", Json::num(s.accesses.external_reads as f64)),
        ("external_writes", Json::num(s.accesses.external_writes as f64)),
        ("edram_energy_j", Json::num(s.edram_energy_j)),
        ("dram_energy_j", Json::num(s.dram_energy_j)),
    ])
}

fn shard_point_json(shards: usize, p: &Point, per_shard: &[KvStoreStats], shard_1: f64) -> Json {
    Json::obj(vec![
        ("shards", Json::num(shards as f64)),
        ("tokens_per_s", Json::num(p.tokens_per_s)),
        ("speedup_vs_1shard", Json::num(p.tokens_per_s / shard_1.max(1e-9))),
        ("tokens", Json::num(p.tokens as f64)),
        ("per_shard_kv", Json::Arr(per_shard.iter().map(shard_kv_json).collect())),
    ])
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BITROM_BENCH_QUICK").is_ok();
    let (n_requests, gen_len) = if quick { (8, 12) } else { (24, 32) };
    let model = ModelConfig::sim_tiny();
    let trace_cfg = TraceConfig {
        n_requests,
        gen_len_min: gen_len.min(8),
        gen_len_max: gen_len,
        vocab_size: model.vocab_size,
        ..TraceConfig::default()
    };

    println!(
        "== bench_serve: offline Server<HostBackend>, {n_requests} requests, gen <= {gen_len} =="
    );

    // axis 1: batching ablation on the serial engine
    println!("-- batches sweep (threads = 1) --");
    let mut batch_points = Vec::new();
    let mut single = 0.0f64;
    for batches in [1usize, 2, 4, 6] {
        let (p, _) = run_point(&model, &trace_cfg, batches, 1, false)?;
        if batches == 1 {
            single = p.tokens_per_s;
        }
        println!(
            "  {batches} batches: {:>8.1} tok/s  (x{:.2} vs single)  \
             TBT p50 {:.3} ms  p95 {:.3} ms",
            p.tokens_per_s,
            p.tokens_per_s / single.max(1e-9),
            p.tbt_p50_ms,
            p.tbt_p95_ms,
        );
        batch_points.push(p);
    }
    let best = batch_points.iter().map(|p| p.tokens_per_s).fold(0f64, f64::max);
    println!("batching speedup: {:.2}x (best vs 1 slot)", best / single.max(1e-9));

    // axis 2: threads sweep at the paper's 6 slots — tokens must be
    // bit-identical at every width (DESIGN.md §12) before any
    // throughput is recorded
    println!("-- threads sweep (batches = 6) --");
    let mut thread_points = Vec::new();
    let mut serial_6 = 0.0f64;
    let mut serial_tokens: Vec<(u64, Vec<i32>)> = Vec::new();
    for threads in [1usize, 2, 4] {
        let (p, tokens) = run_point(&model, &trace_cfg, 6, threads, false)?;
        if threads == 1 {
            serial_6 = p.tokens_per_s;
            serial_tokens = tokens;
        } else {
            assert_eq!(tokens, serial_tokens, "served tokens diverged at {threads} threads");
        }
        println!(
            "  {threads} threads: {:>8.1} tok/s  (x{:.2} vs serial)  \
             TBT p50 {:.3} ms  p95 {:.3} ms",
            p.tokens_per_s,
            p.tokens_per_s / serial_6.max(1e-9),
            p.tbt_p50_ms,
            p.tbt_p95_ms,
        );
        thread_points.push(p);
    }

    // fused-decode axis (DESIGN.md §17): the same trace at 8 in-flight
    // slots, per-slot decode GEMVs vs one batched partition walk per
    // round. Tokens are asserted bit-identical BEFORE any throughput
    // is recorded — a speedup for different tokens is worthless.
    println!("-- fused decode (batches = 8, threads = 1) --");
    let (unfused_p, unfused_tokens) = run_point(&model, &trace_cfg, 8, 1, false)?;
    let (fused_p, fused_tokens) = run_point(&model, &trace_cfg, 8, 1, true)?;
    assert_eq!(
        fused_tokens, unfused_tokens,
        "fused decode changed served tokens (DESIGN.md §17)"
    );
    let fused_speedup = fused_p.tokens_per_s / unfused_p.tokens_per_s.max(1e-9);
    println!(
        "  per-slot: {:>8.1} tok/s | fused: {:>8.1} tok/s  (x{fused_speedup:.2})",
        unfused_p.tokens_per_s, fused_p.tokens_per_s,
    );

    // axis 3: survivability — the same trace under certain periodic
    // retention storms; tokens must still be bit-identical to the
    // fault-free serial run (invariant 9), and the throughput ratio is
    // the measured price of recompute recovery
    println!("-- fault recovery (batches = 6, threads = 1, certain storms) --");
    let (fault_p, fault_tokens, faults) = run_fault_point(&model, &trace_cfg)?;
    assert_eq!(
        fault_tokens, serial_tokens,
        "faulted serving must recover bit-identical tokens"
    );
    let fault_ratio = fault_p.tokens_per_s / serial_6.max(1e-9);
    println!(
        "  storms: {:>8.1} tok/s  (x{:.2} vs fault-free)  \
         {} expiries -> {} recomputes ({} tokens), {} shed",
        fault_p.tokens_per_s,
        fault_ratio,
        faults.retention_events,
        faults.recomputes,
        faults.recomputed_tokens,
        faults.shed.len(),
    );

    // axis 4: streaming overhead — the live admission plane with
    // NDJSON-encoding sinks must reproduce the offline tokens
    // (invariant 10) and keep most of the offline throughput
    println!("-- streaming overhead (live ingress + NDJSON encode, batches = 6, threads = 1) --");
    let (stream_p, stream_tokens, stream_bytes) = run_stream_point(&model, &trace_cfg)?;
    assert_eq!(
        stream_tokens, serial_tokens,
        "streamed tokens must match the offline twin (invariant 10)"
    );
    let stream_ratio = stream_p.tokens_per_s / serial_6.max(1e-9);
    println!(
        "  streamed: {:>8.1} tok/s  (x{:.2} vs offline)  {} wire bytes framed",
        stream_p.tokens_per_s, stream_ratio, stream_bytes,
    );

    // axis 5: shared-prefix capacity gain — the DESIGN.md §15 ledger
    // at its fixed operating point (1 donor + 2 binders, tight
    // DR-eDRAM); tokens must match the private twin (invariant 11)
    // before the reduction is recorded as a gate
    println!("-- shared-prefix reduction (3 requests, common prompt, tight eDRAM) --");
    let prefix = prefix_serving_study(0x9F1C)?;
    assert!(
        prefix.tokens_match,
        "shared-prefix serving must stay bit-identical to its private twin (invariant 11)"
    );
    assert!(
        prefix.measured_shared > FIG5B_MEASURED_BASELINE,
        "shared reduction {:.4} fell to the Fig 5(b) measured baseline {:.4}",
        prefix.measured_shared,
        FIG5B_MEASURED_BASELINE,
    );
    println!(
        "  shared: {:.1}% reduction vs private twin {:.1}% (analytic {:.1}%)  \
         {} hits, {} tokens bound",
        prefix.measured_shared * 100.0,
        prefix.measured_private * 100.0,
        prefix.analytic_shared * 100.0,
        prefix.prefix_hits,
        prefix.kv_shared.prefix_bound_tokens,
    );

    // axis 6: shards sweep — the same trace split across 1/2/4 model
    // shards (DESIGN.md §16). Tokens must be bit-identical at every
    // shard count (invariant 12) before any number is recorded. In
    // this single-process simulation the shards share one core, so the
    // ratio tracks the bookkeeping cost of partition routing +
    // per-shard stores, not a real scale-out curve — the win the sweep
    // demonstrates is tokens-invariance with per-shard placement.
    println!("-- shards sweep (batches = 6, threads = 1) --");
    let mut shard_points = Vec::new();
    let mut shard_1 = 0.0f64;
    for shards in [1usize, 2, 4] {
        let (p, tokens, per_shard) = run_shard_point(&model, &trace_cfg, shards)?;
        assert_eq!(
            tokens, serial_tokens,
            "served tokens diverged at {shards} shards (invariant 12)"
        );
        if shards == 1 {
            shard_1 = p.tokens_per_s;
        }
        let per_shard_accesses: Vec<u64> =
            per_shard.iter().map(|s| s.accesses.total_accesses()).collect();
        println!(
            "  {shards} shards: {:>8.1} tok/s  (x{:.2} vs 1 shard)  \
             per-shard KV accesses {per_shard_accesses:?}",
            p.tokens_per_s,
            p.tokens_per_s / shard_1.max(1e-9),
        );
        shard_points.push((shards, p, per_shard));
    }
    let shard_ratio = shard_points
        .iter()
        .find(|(s, ..)| *s == 4)
        .map(|(_, p, _)| p.tokens_per_s / shard_1.max(1e-9))
        .unwrap_or(0.0);
    println!("shard scaling ratio: {shard_ratio:.2}x (4 shards vs 1 shard)");

    let speedup_6v1 = batch_points
        .iter()
        .find(|p| p.batches == 6)
        .map(|p| p.tokens_per_s / single.max(1e-9))
        .unwrap_or(0.0);
    let threads_4v1 = thread_points
        .iter()
        .find(|p| p.threads == 4)
        .map(|p| p.tokens_per_s / serial_6.max(1e-9))
        .unwrap_or(0.0);
    println!("threads speedup: {threads_4v1:.2}x (4 threads vs serial at 6 batches)");

    let json = Json::obj(vec![
        ("bench", Json::str("bench_serve")),
        ("model", Json::str(model.name.clone())),
        ("quick", Json::Bool(quick)),
        ("requests", Json::num(n_requests as f64)),
        ("gen_len", Json::num(gen_len as f64)),
        (
            "points",
            Json::Arr(
                batch_points
                    .iter()
                    .map(|p| point_json(p, p.tokens_per_s / single.max(1e-9)))
                    .collect(),
            ),
        ),
        (
            "threads_points",
            Json::Arr(
                thread_points
                    .iter()
                    .map(|p| point_json(p, p.tokens_per_s / serial_6.max(1e-9)))
                    .collect(),
            ),
        ),
        (
            "fused_point",
            Json::obj(vec![
                ("batches", Json::num(8.0)),
                ("unfused_tokens_per_s", Json::num(unfused_p.tokens_per_s)),
                ("fused_tokens_per_s", Json::num(fused_p.tokens_per_s)),
                ("speedup", Json::num(fused_speedup)),
                ("tbt_p50_ms", Json::num(fused_p.tbt_p50_ms)),
                ("tbt_p95_ms", Json::num(fused_p.tbt_p95_ms)),
            ]),
        ),
        (
            "fault_point",
            Json::obj(vec![
                ("tokens_per_s", Json::num(fault_p.tokens_per_s)),
                ("throughput_ratio", Json::num(fault_ratio)),
                ("injected_skips", Json::num(faults.injected_skips as f64)),
                ("retention_events", Json::num(faults.retention_events as f64)),
                ("recomputes", Json::num(faults.recomputes as f64)),
                ("recomputed_tokens", Json::num(faults.recomputed_tokens as f64)),
                ("preemptions", Json::num(faults.preemptions as f64)),
                ("shed", Json::num(faults.shed.len() as f64)),
            ]),
        ),
        (
            "stream_point",
            Json::obj(vec![
                ("tokens_per_s", Json::num(stream_p.tokens_per_s)),
                ("throughput_ratio", Json::num(stream_ratio)),
                ("wire_bytes", Json::num(stream_bytes as f64)),
                ("tbt_p50_ms", Json::num(stream_p.tbt_p50_ms)),
                ("tbt_p95_ms", Json::num(stream_p.tbt_p95_ms)),
            ]),
        ),
        (
            "prefix_point",
            Json::obj(vec![
                ("measured_shared", Json::num(prefix.measured_shared)),
                ("measured_private", Json::num(prefix.measured_private)),
                ("analytic_shared", Json::num(prefix.analytic_shared)),
                ("prefix_hits", Json::num(prefix.prefix_hits as f64)),
                (
                    "bound_tokens",
                    Json::num(prefix.kv_shared.prefix_bound_tokens as f64),
                ),
            ]),
        ),
        (
            "shard_points",
            Json::Arr(
                shard_points
                    .iter()
                    .map(|(s, p, ps)| shard_point_json(*s, p, ps, shard_1))
                    .collect(),
            ),
        ),
        (
            "gates",
            Json::obj(vec![
                ("batching_speedup_6v1", Json::num(speedup_6v1)),
                ("threads_speedup_4v1", Json::num(threads_4v1)),
                ("fused_decode_speedup", Json::num(fused_speedup)),
                ("fault_recovery_throughput_ratio", Json::num(fault_ratio)),
                ("streaming_overhead_ratio", Json::num(stream_ratio)),
                ("prefix_hit_dram_reduction", Json::num(prefix.measured_shared)),
                ("shard_scaling_ratio", Json::num(shard_ratio)),
            ]),
        ),
    ]);
    let path = bench_out_path("BENCH_serve.json");
    match std::fs::write(&path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("recorded {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    Ok(())
}
