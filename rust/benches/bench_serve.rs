//! Offline serving throughput vs in-flight batch count: the same trace
//! through `Server<HostBackend>` at 1/2/4/6 slots — the §V-B "pipeline
//! keeps all partitions busy" claim measured end-to-end (batcher +
//! pipeline + KV accounting included), no artifacts needed. Emits
//! `BENCH_serve.json` at the repository root so the serving-perf
//! trajectory is recorded across PRs.
//!
//!   cargo bench --bench bench_serve            # full trace
//!   BITROM_BENCH_QUICK=1 cargo bench --bench bench_serve
//!
//! Override the output path with BITROM_BENCH_OUT.

use bitrom::config::{ModelConfig, ServeConfig};
use bitrom::coordinator::Server;
use bitrom::runtime::HostBackend;
use bitrom::trace::{generate, TraceConfig};
use bitrom::util::bench::bench_out_path;
use bitrom::util::json::Json;

struct Point {
    batches: usize,
    tokens_per_s: f64,
    tbt_p50_ms: f64,
    tbt_p95_ms: f64,
    tokens: u64,
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("BITROM_BENCH_QUICK").is_ok();
    let (n_requests, gen_len) = if quick { (8, 12) } else { (24, 32) };
    let model = ModelConfig::sim_tiny();
    let trace_cfg = TraceConfig {
        n_requests,
        gen_len_min: gen_len.min(8),
        gen_len_max: gen_len,
        vocab_size: model.vocab_size,
        ..TraceConfig::default()
    };

    println!(
        "== bench_serve: offline Server<HostBackend>, {} requests, gen <= {gen_len} ==",
        n_requests
    );
    let mut points = Vec::new();
    let mut single = 0.0f64;
    for batches in [1usize, 2, 4, 6] {
        let backend = HostBackend::new(model.clone(), 0xB17)?;
        let serve = ServeConfig {
            max_batches: batches,
            ..ServeConfig::default()
        };
        let mut server = Server::new(backend, serve)?;
        let (done, mut metrics) = server.run_trace(generate(&trace_cfg))?;
        assert_eq!(done.len(), n_requests, "every request must complete");
        let kv = metrics.kv.as_ref().expect("host backend measures KV stats");
        assert_eq!(kv.retention_failures, 0);
        let tput = metrics.tokens_per_s();
        if batches == 1 {
            single = tput;
        }
        println!(
            "  {batches} batches: {:>8.1} tok/s  (x{:.2} vs single)  \
             TBT p50 {:.3} ms  p95 {:.3} ms",
            tput,
            tput / single.max(1e-9),
            metrics.tbt.pct(50.0) * 1e3,
            metrics.tbt.pct(95.0) * 1e3,
        );
        points.push(Point {
            batches,
            tokens_per_s: tput,
            tbt_p50_ms: metrics.tbt.pct(50.0) * 1e3,
            tbt_p95_ms: metrics.tbt.pct(95.0) * 1e3,
            tokens: metrics.tokens_out,
        });
    }

    let best = points.iter().map(|p| p.tokens_per_s).fold(0f64, f64::max);
    println!(
        "batching speedup: {:.2}x (best vs 1 slot)",
        best / single.max(1e-9)
    );

    let json = Json::obj(vec![
        ("bench", Json::str("bench_serve")),
        ("model", Json::str(model.name.clone())),
        ("quick", Json::Bool(quick)),
        ("requests", Json::num(n_requests as f64)),
        ("gen_len", Json::num(gen_len as f64)),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("batches", Json::num(p.batches as f64)),
                            ("tokens_per_s", Json::num(p.tokens_per_s)),
                            ("tbt_p50_ms", Json::num(p.tbt_p50_ms)),
                            ("tbt_p95_ms", Json::num(p.tbt_p95_ms)),
                            ("tokens", Json::num(p.tokens as f64)),
                            (
                                "speedup_vs_1",
                                Json::num(p.tokens_per_s / single.max(1e-9)),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path = bench_out_path("BENCH_serve.json");
    match std::fs::write(&path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("recorded {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    Ok(())
}
