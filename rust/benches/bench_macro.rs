//! Macro-simulator hot-path benchmarks (the simulator itself must not
//! bottleneck the energy studies — EXPERIMENTS.md §Perf L3).

use bitrom::bitnet::{absmax_quantize, TernaryMatrix};
use bitrom::cirom::{AdderTree, BitRomMacro, EventCounters, Trimla};
use bitrom::config::MacroGeometry;
use bitrom::util::bench::bench_config;
use bitrom::util::rng::Rng;

fn main() {
    let b = bench_config();
    let mut rng = Rng::new(42);

    // TriMLA single step
    let r = b.run("trimla_step (1 MAC)", || {
        let mut t = Trimla::new(8);
        let mut ev = EventCounters::new();
        for i in 0..8 {
            t.step(((i % 3) as i8) - 1, (i % 15) as i32, &mut ev);
        }
        (t.output(), ev.macs)
    });
    println!("{}", r.report());

    // adder tree pass
    let tree = AdderTree::new(128);
    let partials: Vec<i32> = (0..128).map(|i| (i * 7 % 255) - 127).collect();
    let r = b.run("adder_tree_reduce (128-in)", || {
        let mut ev = EventCounters::new();
        tree.reduce(&partials, &mut ev)
    });
    println!("{}", r.report());

    // full-geometry single-channel GEMV, 4b and 8b
    let geom = MacroGeometry::default();
    for (bits, label) in [(4usize, "4b"), (8usize, "8b bit-serial")] {
        let w = TernaryMatrix::random(2048, 1, 0.3, &mut rng);
        let m = BitRomMacro::fabricate(geom.clone(), &w);
        let x: Vec<f32> = (0..2048).map(|_| rng.normal() as f32).collect();
        let acts = absmax_quantize(&x, bits);
        let r = b.run(&format!("macro_gemv 2048x1 {label}"), || {
            let mut ev = EventCounters::new();
            m.gemv(&acts, &mut ev)
        });
        println!("{}", r.report());
    }

    // block GEMV: 2048 inputs x 256 outputs (one partition-scale tile)
    let w = TernaryMatrix::random(2048, 256, 0.3, &mut rng);
    let m = BitRomMacro::fabricate(geom.clone(), &w);
    let x: Vec<f32> = (0..2048).map(|_| rng.normal() as f32).collect();
    let acts = absmax_quantize(&x, 8);
    let r = b.run("macro_gemv 2048x256 8b", || {
        let mut ev = EventCounters::new();
        m.gemv(&acts, &mut ev)
    });
    println!("{}", r.report());
    let macs = 2048.0 * 256.0;
    println!(
        "  -> simulated MAC rate: {:.1} MMAC/s",
        macs / (r.mean_ns / 1e9) / 1e6
    );
}
