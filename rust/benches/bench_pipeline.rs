//! Pipeline benchmarks: schedule generation (pure) and the batched
//! serving throughput vs batch size — the §V-B "6-stage pipeline keeps
//! all partitions busy" claim, measured.

use bitrom::config::ServeConfig;
use bitrom::coordinator::{PipelineSchedule, Server};
use bitrom::runtime::{Manifest, ModelExecutor};
use bitrom::trace::{generate, TraceConfig};
use bitrom::util::bench::bench_config;

fn main() -> anyhow::Result<()> {
    let b = bench_config();

    // pure schedule generation
    let slots: Vec<usize> = (0..6).collect();
    let r = b.run("pipeline_schedule 6x6", || {
        PipelineSchedule::for_round(&slots, 6)
    });
    println!("{}", r.report());
    let sched = PipelineSchedule::for_round(&slots, 6);
    println!(
        "  one-round utilization {:.1}% over {} cycles (steady-state interior: 100%)",
        100.0 * sched.utilization(6),
        sched.n_cycles
    );

    // serving throughput vs batch size (needs artifacts)
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP serving section: artifacts not built");
        return Ok(());
    }
    println!("\nthroughput vs in-flight batches (12 requests, 16 gen tokens):");
    let mut single = 0.0;
    for batches in [1usize, 2, 4, 6] {
        let exec = ModelExecutor::load(&dir)?;
        let serve = ServeConfig {
            max_batches: batches,
            ..ServeConfig::default()
        };
        let trace = TraceConfig {
            n_requests: 12,
            gen_len_min: 16,
            gen_len_max: 16,
            vocab_size: exec.manifest.model.vocab_size,
            ..TraceConfig::default()
        };
        let mut server = Server::new(exec, serve)?;
        let (_, mut metrics) = server.run_trace(generate(&trace))?;
        let tput = metrics.tokens_per_s();
        if batches == 1 {
            single = tput;
        }
        println!(
            "  {batches} batches: {:>7.1} tok/s  (x{:.2} vs single)  median TBT {:.2} ms",
            tput,
            tput / single.max(1e-9),
            metrics.tbt.pct(50.0) * 1e3
        );
    }
    Ok(())
}
