//! Table III regenerator: computes the "This Work" design point through
//! BOTH the closed-form model and the event-counting circuit simulator,
//! prints the full comparison table, and benchmarks the evaluation
//! itself.

use bitrom::bitnet::{absmax_quantize, TernaryMatrix};
use bitrom::cirom::{BitRomMacro, EventCounters};
use bitrom::config::{HardwareConfig, MacroGeometry, TechNode};
use bitrom::energy::EnergyModel;
use bitrom::report::table3_report;
use bitrom::util::bench::bench_config;
use bitrom::util::rng::Rng;

fn main() {
    // measured ROM sparsity from the artifacts if available
    let sparsity = bitrom::runtime::Manifest::load(&bitrom::runtime::Manifest::default_dir())
        .map(|m| m.rom_sparsity)
        .unwrap_or(0.30);

    println!("{}", table3_report(sparsity));

    // cross-check: simulator vs closed form at the design point
    let mut rng = Rng::new(7);
    let geom = MacroGeometry::default();
    let w = TernaryMatrix::random(2048, 8, sparsity, &mut rng);
    let mac = BitRomMacro::fabricate(geom, &w);
    let x: Vec<f32> = (0..2048).map(|_| rng.normal() as f32).collect();
    let acts = absmax_quantize(&x, 4);
    let mut ev = EventCounters::new();
    mac.gemv(&acts, &mut ev);
    let model = EnergyModel::new(HardwareConfig::default());
    let sim = model.tops_per_watt(&ev);
    let ana = model.tops_per_watt_analytic(w.sparsity(), 4);
    println!(
        "design point cross-check @0.6V/4b: simulator {sim:.2} TOPS/W vs closed form {ana:.2} \
         (paper: 20.8); skip rate {:.1}%",
        100.0 * ev.skip_rate()
    );
    println!(
        "bit density: {:.0} kb/mm2 (paper: 4,967)",
        HardwareConfig::default()
            .geometry
            .bit_density_kb_mm2(TechNode::N65)
    );

    // sparsity sensitivity sweep (the TriMLA zero-skip benefit)
    println!("\nsparsity sweep (0.6V, 4b):");
    for s in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7] {
        println!(
            "  sparsity {:.1}: {:>5.1} TOPS/W",
            s,
            model.tops_per_watt_analytic(s, 4)
        );
    }

    // ablation: local-then-global vs per-group adder trees. A per-group
    // tree fires every cycle (per 8 MACs) instead of once per channel
    // pass per TriMLA group of `rows` MACs — the energy delta is the
    // architecture's headline saving.
    let e = &model.hw.energy;
    let per_mac_lg = e.tree_pass_fj / (128.0 * 8.0);
    let per_mac_pg = e.tree_pass_fj / 8.0;
    println!(
        "\nadder-tree ablation (tree energy per MAC): local-then-global {per_mac_lg:.2} fJ \
         vs per-group {per_mac_pg:.1} fJ ({:.0}x saving on the tree component)",
        per_mac_pg / per_mac_lg
    );

    // benchmark the evaluation machinery
    let b = bench_config();
    let r = b.run("table3_full_report", || table3_report(sparsity));
    println!("\n{}", r.report());
}
