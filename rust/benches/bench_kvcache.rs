//! KV-cache benchmarks: the tiered quantized store's append/gather
//! hot path (it now carries every host-backend attention read), the
//! analytic manager's accounting throughput, and raw DR-eDRAM access
//! costs.

use bitrom::config::{EdramParams, ModelConfig, ServeConfig};
use bitrom::dram::DramParams;
use bitrom::kvcache::{KvCacheManager, KvQuant, KvStore, KvStoreConfig};
use bitrom::util::bench::bench_config;
use bitrom::util::rng::Rng;

/// One full 128-token decode through the store: append + gather every
/// step with read counting (the serving data-plane workload).
fn store_decode(quant: KvQuant, model: &ModelConfig) -> f64 {
    let mut store = KvStore::new(KvStoreConfig {
        kv_dim: model.kv_dim(),
        n_layers: model.n_layers,
        block_tokens: 8,
        ondie_tokens: 32,
        quant,
        edram: EdramParams::default(),
        dram: DramParams::default(),
    });
    let mut seq = store.new_seq();
    let mut rng = Rng::new(3);
    let row: Vec<f32> = (0..model.kv_dim()).map(|_| rng.normal() as f32).collect();
    let (mut k, mut v) = (Vec::new(), Vec::new());
    for t in 0..128usize {
        store.set_now(t as f64 * 0.005);
        for layer in 0..model.n_layers {
            store.append(&mut seq, layer, &row, &row);
            store.gather(&seq, layer, t + 1, true, &mut k, &mut v).unwrap();
        }
    }
    store.stats().external_reduction()
}

fn main() {
    let b = bench_config();
    let model = ModelConfig::sim_tiny();
    let serve = ServeConfig::default();

    // the real data plane: quantize-on-write + dequantize-on-read
    let r = b.run("kv_store q8 full 128-token decode (6 layers)", || {
        store_decode(KvQuant::Q8, &model)
    });
    println!("{}", r.report());
    let r = b.run("kv_store f32 full 128-token decode (6 layers)", || {
        store_decode(KvQuant::F32, &model)
    });
    println!("{}", r.report());

    // full-sequence accounting (128 tokens, 6 layers)
    let r = b.run("kv_manager full 128-token sequence", || {
        let mut kv = KvCacheManager::new(&model, &serve, EdramParams::default());
        kv.start_seq(0);
        kv.prefill(0, 8, 0.0);
        for step in 0..120usize {
            let now = (step + 1) as f64 * 0.005;
            kv.write_token(0, now);
            kv.read_context(0, now).unwrap();
        }
        kv.stats.external_reduction()
    });
    println!("{}", r.report());

    // shared-prefix accounting: a donor decodes the full sequence, a
    // binder binds one full block of its prompt (DESIGN.md §15) and
    // writes only the tail — the bound reads route to the donor's rows
    let r = b.run("kv_manager bound-prefix 128-token pair", || {
        let mut kv = KvCacheManager::new(&model, &serve, EdramParams::default());
        let mut now = 0.0;
        kv.start_seq(0);
        kv.prefill(0, 9, now);
        for _ in 0..119usize {
            now += 0.005;
            kv.write_token(0, now);
            kv.read_context(0, now).unwrap();
        }
        kv.start_seq(1);
        kv.bind_prefix(1, 0, 8);
        now += 0.005;
        kv.prefill(1, 1, now);
        for _ in 0..119usize {
            now += 0.005;
            kv.write_token(1, now);
            kv.read_context(1, now).unwrap();
        }
        kv.stats.external_reduction()
    });
    println!("{}", r.report());

    // single decode-step accounting at max context
    let mut kv = KvCacheManager::new(&model, &serve, EdramParams::default());
    kv.start_seq(0);
    kv.prefill(0, 8, 0.0);
    for step in 0..119usize {
        let now = (step + 1) as f64 * 0.005;
        kv.write_token(0, now);
        kv.read_context(0, now).unwrap();
    }
    // continue the retention clock from where the setup loop left it —
    // a time jump past tREF would (correctly) trip the DR check.
    let mut t = 119.0 * 0.005;
    let r = b.run("kv_manager read_context @127 tokens", || {
        t += 0.005;
        kv.read_context(0, t).unwrap();
        kv.stats.ondie_reads
    });
    println!("{}", r.report());

    // eDRAM raw ops
    let mut e = bitrom::edram::DrEdram::new(EdramParams::default());
    e.write(0, 64, 0.0);
    let mut now = 0.0f64;
    let r = b.run("edram read (refresh-on-read)", || {
        now += 1e-4;
        e.read(0, 64, now).unwrap();
        e.reads
    });
    println!("{}", r.report());
}
