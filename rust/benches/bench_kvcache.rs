//! KV-cache manager benchmarks: decode-step accounting throughput and
//! DR-eDRAM access costs (the manager runs on the serving hot path, so
//! its overhead must be negligible vs a PJRT partition execution).

use bitrom::config::{EdramParams, ModelConfig, ServeConfig};
use bitrom::kvcache::KvCacheManager;
use bitrom::util::bench::bench_config;

fn main() {
    let b = bench_config();
    let model = ModelConfig::sim_tiny();
    let serve = ServeConfig::default();

    // full-sequence accounting (128 tokens, 6 layers)
    let r = b.run("kv_manager full 128-token sequence", || {
        let mut kv = KvCacheManager::new(&model, &serve, EdramParams::default());
        kv.start_seq(0);
        kv.prefill(0, 8, 0.0);
        for step in 0..120usize {
            let now = (step + 1) as f64 * 0.005;
            kv.write_token(0, now);
            kv.read_context(0, now).unwrap();
        }
        kv.stats.external_reduction()
    });
    println!("{}", r.report());

    // single decode-step accounting at max context
    let mut kv = KvCacheManager::new(&model, &serve, EdramParams::default());
    kv.start_seq(0);
    kv.prefill(0, 8, 0.0);
    for step in 0..119usize {
        let now = (step + 1) as f64 * 0.005;
        kv.write_token(0, now);
        kv.read_context(0, now).unwrap();
    }
    // continue the retention clock from where the setup loop left it —
    // a time jump past tREF would (correctly) trip the DR check.
    let mut t = 119.0 * 0.005;
    let r = b.run("kv_manager read_context @127 tokens", || {
        t += 0.005;
        kv.read_context(0, t).unwrap();
        kv.stats.ondie_reads
    });
    println!("{}", r.report());

    // eDRAM raw ops
    let mut e = bitrom::edram::DrEdram::new(EdramParams::default());
    e.write(0, 64, 0.0);
    let mut now = 0.0f64;
    let r = b.run("edram read (refresh-on-read)", || {
        now += 1e-4;
        e.read(0, 64, now).unwrap();
        e.reads
    });
    println!("{}", r.report());
}
