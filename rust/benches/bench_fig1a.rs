//! Fig 1(a) regenerator + benchmark of the area-model sweep.

use bitrom::config::HardwareConfig;
use bitrom::report::fig1a_report;
use bitrom::util::bench::bench_config;

fn main() {
    let hw = HardwareConfig::default();
    println!("{}", fig1a_report(&hw));
    let b = bench_config();
    let r = b.run("fig1a_area_sweep", || fig1a_report(&hw));
    println!("{}", r.report());
}
