//! Fig 5(b) regenerator + benchmark: the reduction grid via the
//! step-simulator and the closed form, including the paper point check.

use bitrom::kvcache::{
    closed_form_reduction, reduction_sweep, simulate_reduction, PAPER_BUFFERS, PAPER_SEQ_LENS,
};
use bitrom::report::fig5b_report;
use bitrom::util::bench::bench_config;

fn main() {
    println!("{}", fig5b_report());

    let paper = simulate_reduction(128, 32);
    assert!((paper - 0.436).abs() < 0.001);
    println!("paper point (seq 128, 32 buffered): {:.1}% — matches 43.6%\n", paper * 100.0);

    let b = bench_config();
    let r = b.run("fig5b_grid_simulated", || {
        reduction_sweep(&PAPER_SEQ_LENS, &PAPER_BUFFERS)
    });
    println!("{}", r.report());
    let r = b.run("fig5b_grid_closed_form", || {
        let mut acc = 0.0;
        for &s in &PAPER_SEQ_LENS {
            for &buf in &PAPER_BUFFERS {
                acc += closed_form_reduction(s, buf);
            }
        }
        acc
    });
    println!("{}", r.report());
}
