//! Host GEMV/GEMM kernel benchmarks: per-trit base-3 reference vs the
//! word-parallel bitplane engine at LLaMA-shaped sizes across
//! sparsities (EXPERIMENTS.md §Perf). Emits `BENCH_gemv.json` at the
//! repository root so the perf trajectory is recorded across PRs.
//!
//!   cargo bench --bench bench_gemv            # full sweep (~minutes)
//!   BITROM_BENCH_QUICK=1 cargo bench --bench bench_gemv
//!
//! Override the output path with BITROM_BENCH_OUT.

use bitrom::report::{gemv_perf_json, gemv_perf_study, gemv_perf_table};
use bitrom::util::bench::bench_out_path;

fn main() {
    let points = gemv_perf_study(false);
    println!("{}", gemv_perf_table(&points));

    // the acceptance bar this bench exists to watch: ≥ 8x over the
    // reference at 2048x2048 / 30% sparsity
    if let Some(p) = points
        .iter()
        .find(|p| p.rows == 2048 && p.cols == 2048 && (p.sparsity - 0.3).abs() < 1e-9)
    {
        let s = p.speedup();
        println!(
            "2048x2048 @ 0.3 sparsity: {s:.1}x gemv, {:.1}x batched gemm {}",
            p.gemm_speedup(),
            if s >= 8.0 { "(PASS: >= 8x bar)" } else { "(BELOW the 8x bar!)" }
        );
    }

    let path = bench_out_path("BENCH_gemv.json");
    let json = gemv_perf_json(&points, "bench_gemv");
    match std::fs::write(&path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("recorded {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
