//! Host GEMV/GEMM kernel benchmarks: per-trit base-3 reference vs the
//! word-parallel bitplane engine at LLaMA-shaped sizes across
//! sparsities, plus the kernel threads sweep (sharded GEMM at 1/2/4
//! pool workers — EXPERIMENTS.md §Perf, §Threads). Emits
//! `BENCH_gemv.json` at the repository root so the perf trajectory is
//! recorded across PRs; its `gates` object feeds the CI
//! perf-regression gate (`ci/check_bench.py` vs `BENCH_baseline/`).
//!
//!   cargo bench --bench bench_gemv            # full sweep (~minutes)
//!   BITROM_BENCH_QUICK=1 cargo bench --bench bench_gemv
//!
//! Override the output path with BITROM_BENCH_OUT.

use bitrom::report::{
    gemm_threads_sweep, gemm_threads_table, gemv_perf_json, gemv_perf_study, gemv_perf_table,
    threads_speedup,
};
use bitrom::util::bench::bench_out_path;

fn main() {
    let points = gemv_perf_study(false);
    println!("{}", gemv_perf_table(&points));

    // the acceptance bar this bench exists to watch: ≥ 8x over the
    // reference at 2048x2048 / 30% sparsity
    if let Some(p) = points
        .iter()
        .find(|p| p.rows == 2048 && p.cols == 2048 && (p.sparsity - 0.3).abs() < 1e-9)
    {
        let s = p.speedup();
        println!(
            "2048x2048 @ 0.3 sparsity: {s:.1}x gemv, {:.1}x batched gemm {}",
            p.gemm_speedup(),
            if s >= 8.0 { "(PASS: >= 8x bar)" } else { "(BELOW the 8x bar!)" }
        );
    }

    // kernel threads sweep: sharded GEMM vs the serial kernel (always
    // at the full 2048x2048 shape so fork cost is amortized; every
    // width is asserted bit-identical before timing)
    let tpoints = gemm_threads_sweep(false);
    println!("{}", gemm_threads_table(&tpoints));
    if let Some(s4) = threads_speedup(&tpoints, 4) {
        println!(
            "4-thread gemm speedup: {s4:.2}x {}",
            if s4 > 1.5 { "(PASS: > 1.5x bar)" } else { "(BELOW the 1.5x bar!)" }
        );
    }

    let path = bench_out_path("BENCH_gemv.json");
    let json = gemv_perf_json(&points, &tpoints, "bench_gemv");
    match std::fs::write(&path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("recorded {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
