#!/usr/bin/env python3
"""Perf-regression gate: compare a freshly generated BENCH_*.json
against its committed snapshot in BENCH_baseline/.

Every bench binary records a top-level ``gates`` object of scale-free,
higher-is-better metrics (speedups and throughput ratios measured
within one run on one machine — unlike absolute tok/s or ns, these are
comparable across CI runners). This script fails the job when any
metric shared by the fresh record and the baseline has dropped by more
than the tolerance.

Usage:
    python3 ci/check_bench.py BENCH_gemv.json [BENCH_baseline/BENCH_gemv.json]

    (the baseline path defaults to BENCH_baseline/<fresh basename>)

Knobs (documented in EXPERIMENTS.md §Threads):
    BITROM_BENCH_GATE=off   skip the gate entirely (local experiments,
                            emergency override for a flaky runner)
    BITROM_BENCH_TOL=0.25   relative drop tolerated before failing
                            (default 0.25; quick-mode records — those
                            with "quick": true — default to 0.40, since
                            their short measurement windows are noisy)

Metrics present in only one of the two files are reported and skipped,
not failed: quick and full sweeps measure different shape sets, and new
gates need one green run before they can be baselined. Baselines are
conservative floors seeded from early CI history — ratchet them up as
the history accumulates (copy a healthy run's gates over the snapshot).
"""

import json
import os
import sys


def load(path, role):
    """Read one bench record; on any problem return (None, one-line
    reason) instead of letting a traceback swallow the real failure."""
    try:
        with open(path) as f:
            record = json.load(f)
    except OSError as e:
        return None, f"{role} {path} is unreadable ({e.strerror or e})"
    except json.JSONDecodeError as e:
        return None, f"{role} {path} is not valid JSON (line {e.lineno}: {e.msg})"
    if not isinstance(record, dict):
        return None, f"{role} {path} is not a JSON object"
    return record, None


def main(argv):
    if os.environ.get("BITROM_BENCH_GATE", "").lower() in ("off", "0", "false"):
        print("check_bench: BITROM_BENCH_GATE=off — gate skipped")
        return 0
    if len(argv) < 2 or len(argv) > 3:
        print(__doc__)
        return 2

    fresh_path = argv[1]
    base_path = (
        argv[2]
        if len(argv) == 3
        else os.path.join("BENCH_baseline", os.path.basename(fresh_path))
    )
    if not os.path.exists(fresh_path):
        print(f"check_bench: FAIL — fresh record {fresh_path} was not generated")
        return 1
    if not os.path.exists(base_path):
        print(f"check_bench: no baseline at {base_path} — nothing to gate (commit one)")
        return 0

    fresh, err = load(fresh_path, "fresh record")
    if err is None:
        base, err = load(base_path, "baseline")
    if err is not None:
        print(f"check_bench: FAIL — {err}")
        return 1
    fresh_gates = fresh.get("gates", {})
    base_gates = base.get("gates", {})
    for name, gates, path in (("fresh record", fresh_gates, fresh_path),
                              ("baseline", base_gates, base_path)):
        if not isinstance(gates, dict):
            print(f"check_bench: FAIL — {name} {path} gates is not an object")
            return 1
    if not fresh_gates:
        print(f"check_bench: FAIL — {fresh_path} carries no gates object")
        return 1

    quick = bool(fresh.get("quick", False))
    default_tol = 0.40 if quick else 0.25
    tol = float(os.environ.get("BITROM_BENCH_TOL", default_tol))

    shared = sorted(set(fresh_gates) & set(base_gates))
    skipped = sorted(set(fresh_gates) ^ set(base_gates))
    failures = []
    print(
        f"check_bench: {fresh_path} vs {base_path} "
        f"(tolerance {tol:.0%}{', quick mode' if quick else ''})"
    )
    for name in shared:
        try:
            got, want = float(fresh_gates[name]), float(base_gates[name])
        except (TypeError, ValueError):
            print(
                f"check_bench: FAIL — gate {name!r} is not numeric "
                f"(fresh {fresh_gates[name]!r}, baseline {base_gates[name]!r})"
            )
            return 1
        floor = want * (1.0 - tol)
        verdict = "ok" if got >= floor else "REGRESSION"
        print(f"  {name:<40} {got:8.3f} vs baseline {want:8.3f} (floor {floor:.3f}) {verdict}")
        if got < floor:
            failures.append(name)
    for name in skipped:
        where = "baseline" if name in base_gates else "fresh record"
        print(f"  {name:<40} only in {where} — skipped")

    if not shared:
        print("check_bench: WARNING — no shared gate metrics; the gate is vacuous")
        return 0
    if failures:
        print(
            f"check_bench: FAIL — {len(failures)} metric(s) regressed more than {tol:.0%}: "
            + ", ".join(failures)
        )
        print("  (override once with BITROM_BENCH_GATE=off; tune with BITROM_BENCH_TOL)")
        return 1
    print(f"check_bench: OK — {len(shared)} metric(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
