"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes, sparsity levels, activation bit-widths and
block geometries; every configuration must match ref.py to f32 tolerance
(the arithmetic is exact-integer under the hood, so tolerances are tight).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant
from compile.kernels import ref
from compile.kernels.lora import lora_delta
from compile.kernels.ternary_matmul import ternary_matmul, vmem_bytes

RNG = np.random.default_rng(1234)


def make_inputs(m, k, n, sparsity=None, act_bits=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    if sparsity is not None:
        mask = rng.random((k, n)) < sparsity
        w[mask] = 0.0
    x_q, x_s = quant.absmax_quantize(jnp.asarray(x), act_bits)
    w_q, w_s = quant.absmean_ternary(jnp.asarray(w))
    return x_q, w_q, x_s, w_s


@st.composite
def shapes(draw):
    m = draw(st.integers(1, 48))
    k = draw(st.integers(1, 200))
    n = draw(st.integers(1, 96))
    return m, k, n


class TestTernaryMatmul:
    @settings(max_examples=25, deadline=None)
    @given(shapes(), st.sampled_from([4, 8]), st.integers(0, 2**31 - 1))
    def test_matches_ref(self, mkn, act_bits, seed):
        m, k, n = mkn
        x_q, w_q, x_s, w_s = make_inputs(m, k, n, act_bits=act_bits, seed=seed)
        y = ternary_matmul(x_q, w_q, x_s, w_s, block_m=16, block_n=32, block_k=32)
        y_ref = ref.ternary_matmul_ref(x_q, w_q, x_s, w_s)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(shapes(), st.integers(0, 2**31 - 1))
    def test_bit_serial_matches_direct(self, mkn, seed):
        """TriMLA's two-cycle 4-bit mode must be numerically identical."""
        m, k, n = mkn
        x_q, w_q, x_s, w_s = make_inputs(m, k, n, seed=seed)
        y_direct = ternary_matmul(x_q, w_q, x_s, w_s, block_m=16, block_n=32, block_k=32)
        y_serial = ternary_matmul(
            x_q, w_q, x_s, w_s, bit_serial=True, block_m=16, block_n=32, block_k=32
        )
        np.testing.assert_allclose(
            np.asarray(y_serial), np.asarray(y_direct), rtol=1e-6, atol=1e-6
        )

    @pytest.mark.parametrize("sparsity", [0.0, 0.3, 0.7, 1.0])
    def test_sparsity_levels(self, sparsity):
        x_q, w_q, x_s, w_s = make_inputs(8, 128, 64, sparsity=sparsity)
        y = ternary_matmul(x_q, w_q, x_s, w_s)
        y_ref = ref.ternary_matmul_ref(x_q, w_q, x_s, w_s)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-5)
        if sparsity == 1.0:
            assert float(jnp.max(jnp.abs(y))) == 0.0

    @pytest.mark.parametrize(
        "bm,bn,bk", [(8, 8, 8), (16, 64, 32), (128, 128, 128), (32, 16, 256)]
    )
    def test_block_shapes(self, bm, bn, bk):
        """Result is invariant to the BlockSpec tiling (the HBM↔VMEM
        schedule changes, the math must not)."""
        x_q, w_q, x_s, w_s = make_inputs(24, 200, 96)
        y = ternary_matmul(x_q, w_q, x_s, w_s, block_m=bm, block_n=bn, block_k=bk)
        y_ref = ref.ternary_matmul_ref(x_q, w_q, x_s, w_s)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-5)

    def test_exact_integer_accumulation(self):
        """With unit scales the kernel must be exactly integral."""
        x_q = jnp.asarray(RNG.integers(-127, 128, size=(4, 64)), jnp.float32)
        w_q = jnp.asarray(RNG.integers(-1, 2, size=(64, 16)), jnp.float32)
        y = ternary_matmul(x_q, w_q, jnp.ones((4, 1)), 1.0, block_m=4, block_n=16, block_k=16)
        assert np.array_equal(np.asarray(y), np.round(np.asarray(y)))

    def test_local_global_ordering_is_exact(self):
        """The local-then-global grouping (TriMLA -> adder tree) changes
        nothing in exact integer arithmetic."""
        x_q, w_q, x_s, w_s = make_inputs(8, 130, 40)
        a = ref.ternary_matmul_ref(x_q, w_q, x_s, w_s)
        for group in (2, 8, 13, 64):
            b = ref.ternary_matmul_local_global_ref(x_q, w_q, x_s, w_s, group=group)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)

    def test_bit_serial_digit_decomposition(self):
        """hi/lo split: x == 16*hi + lo, lo in [0,16), hi in [-8,8]."""
        x = jnp.asarray(np.arange(-127, 128), jnp.float32)
        hi, lo = ref.bit_serial_split(x)
        assert np.array_equal(np.asarray(16.0 * hi + lo), np.asarray(x))
        assert float(jnp.min(lo)) >= 0.0 and float(jnp.max(lo)) <= 15.0
        assert float(jnp.min(hi)) >= -8.0 and float(jnp.max(hi)) <= 8.0

    def test_vmem_budget(self):
        """Default blocks fit comfortably in a 16 MiB TPU VMEM."""
        assert vmem_bytes(128, 128, 128) < 16 * 2**20 // 4


class TestLoraKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        st.integers(1, 40),
        st.integers(4, 96),
        st.integers(4, 64),
        st.sampled_from([4, 8, 16]),
        st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, k, n, rank, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        a = jnp.asarray(rng.normal(size=(k, rank)) * 0.1, jnp.float32)
        b = jnp.asarray(rng.normal(size=(rank, n)) * 0.1, jnp.float32)
        a_q, a_s = quant.quantize_kbit(a, 6)
        b_q, b_s = quant.quantize_kbit(b, 6)
        y = lora_delta(x, a_q, b_q, a_s, b_s, alpha=32.0, rank=rank)
        y_ref = ref.lora_ref(x, a_q * a_s, b_q * b_s, 32.0, rank)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-5)

    def test_zero_b_gives_zero_delta(self):
        """LoRA inits B=0: the adapter starts as an exact no-op."""
        x = jnp.asarray(RNG.normal(size=(8, 32)), jnp.float32)
        a = jnp.asarray(RNG.normal(size=(32, 16)), jnp.float32)
        b = jnp.zeros((16, 24), jnp.float32)
        y = lora_delta(x, a, b, 1.0, 1.0, alpha=32.0, rank=16)
        assert float(jnp.max(jnp.abs(y))) == 0.0

    @pytest.mark.parametrize("bits", [2, 4, 6, 8])
    def test_quant_bits_sweep(self, bits):
        """Fig 6(a) machinery: the kernel must be exact at any adapter
        bit-width (accuracy effects are a model property, not a kernel
        property)."""
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(6, 48)), jnp.float32)
        a = jnp.asarray(rng.normal(size=(48, 16)) * 0.1, jnp.float32)
        b = jnp.asarray(rng.normal(size=(16, 32)) * 0.1, jnp.float32)
        a_q, a_s = quant.quantize_kbit(a, bits)
        b_q, b_s = quant.quantize_kbit(b, bits)
        y = lora_delta(x, a_q, b_q, a_s, b_s, alpha=32.0, rank=16)
        y_ref = ref.lora_ref(x, a_q * a_s, b_q * b_s, 32.0, 16)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-5)
