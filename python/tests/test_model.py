"""L2 model tests: shapes, KV-cache semantics, GQA, LoRA, quant paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import quant
from compile.configs import SIM_TINY, SIM_SMALL, FALCON3_1B, get_config


@pytest.fixture(scope="module")
def tiny():
    cfg = SIM_TINY
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rom = M.rom_image(params, cfg)
    return cfg, params, rom


class TestConfig:
    def test_head_dim(self):
        assert SIM_TINY.head_dim == 32
        assert FALCON3_1B.head_dim == 256

    def test_partitioning(self):
        assert SIM_TINY.layers_per_partition == 1
        assert FALCON3_1B.layers_per_partition == 3  # paper §V-B

    def test_gqa_group(self):
        assert SIM_TINY.gqa_group == 2
        assert FALCON3_1B.gqa_group == 2

    def test_param_count_matches_arrays(self, tiny):
        cfg, params, _ = tiny
        n = sum(int(np.prod(v.shape)) for v in jax.tree_util.tree_leaves(params))
        assert n == cfg.param_count()

    def test_falcon3_1b_is_billion_scale(self):
        assert 1.2e9 < FALCON3_1B.param_count() < 2.0e9

    def test_unknown_config_raises(self):
        with pytest.raises(KeyError):
            get_config("nope")


class TestRomImage:
    def test_all_linears_ternary(self, tiny):
        _, _, rom = tiny
        for lq in rom["layers"]:
            for name in M.LINEAR_KEYS:
                vals = np.unique(np.asarray(lq[name]["w_q"]))
                assert set(vals.tolist()) <= {-1.0, 0.0, 1.0}

    def test_sparsity_nontrivial(self, tiny):
        _, _, rom = tiny
        s = M.rom_sparsity(rom)
        assert 0.05 < s < 0.8  # gaussian init → roughly 1/3 zeros

    def test_rom_is_deterministic(self):
        cfg = SIM_TINY
        r1 = M.rom_image(M.init_params(cfg, jax.random.PRNGKey(7)), cfg)
        r2 = M.rom_image(M.init_params(cfg, jax.random.PRNGKey(7)), cfg)
        np.testing.assert_array_equal(
            np.asarray(r1["layers"][0]["q"]["w_q"]),
            np.asarray(r2["layers"][0]["q"]["w_q"]),
        )


class TestKVCache:
    def test_prefill_equals_incremental_decode(self, tiny):
        """DESIGN.md invariant 4: prefill(S) ≡ prefill(S-j) + j decodes."""
        cfg, _, rom = tiny
        prompt = jnp.asarray([3, 7, 11, 42, 99, 250, 1, 0], jnp.int32)
        S = prompt.shape[0]

        kc, vc = M.empty_caches(cfg)
        full_logits, _, _ = M.full_fwd(rom, cfg, prompt, jnp.arange(S), kc, vc)

        kc, vc = M.empty_caches(cfg)
        _, kc, vc = M.full_fwd(rom, cfg, prompt[:5], jnp.arange(5), kc, vc)
        for pos in range(5, S):
            logits, kc, vc = M.full_fwd(
                rom, cfg, prompt[pos : pos + 1], jnp.asarray([pos]), kc, vc
            )
            np.testing.assert_allclose(
                np.asarray(logits[0]),
                np.asarray(full_logits[pos]),
                rtol=2e-4,
                atol=2e-4,
            )

    def test_cache_rows_written_at_positions(self, tiny):
        cfg, _, rom = tiny
        kc, vc = M.empty_caches(cfg)
        toks = jnp.asarray([5, 6, 7], jnp.int32)
        _, kc, vc = M.full_fwd(rom, cfg, toks, jnp.arange(3), kc, vc)
        k0 = np.asarray(kc[0])
        assert np.abs(k0[:3]).sum() > 0  # written
        assert np.abs(k0[3:]).sum() == 0  # untouched

    def test_padded_positions_never_visible(self, tiny):
        """Garbage beyond the causal horizon must not change results —
        the property that lets the rust coordinator use a fixed prefill
        bucket with padded prompts."""
        cfg, _, rom = tiny
        prompt = jnp.asarray([9, 8, 7, 6], jnp.int32)
        pad = jnp.asarray([9, 8, 7, 6, 123, 45, 201, 77], jnp.int32)  # junk tail
        kc, vc = M.empty_caches(cfg)
        l_exact, _, _ = M.full_fwd(rom, cfg, prompt, jnp.arange(4), kc, vc)
        kc, vc = M.empty_caches(cfg)
        l_padded, _, _ = M.full_fwd(rom, cfg, pad, jnp.arange(8), kc, vc)
        np.testing.assert_allclose(
            np.asarray(l_exact[3]), np.asarray(l_padded[3]), rtol=2e-4, atol=2e-4
        )


class TestAttention:
    def test_gqa_repeats_kv(self, tiny):
        cfg, _, _ = tiny
        S = 4
        q = jnp.ones((S, cfg.n_heads, cfg.head_dim))
        kc = jnp.zeros((cfg.max_seq, cfg.n_kv_heads, cfg.head_dim))
        vc = jnp.zeros((cfg.max_seq, cfg.n_kv_heads, cfg.head_dim))
        vc = vc.at[:S].set(1.0)
        kc = kc.at[:S].set(1.0)
        out = M.attention(q, kc, vc, jnp.arange(S), cfg)
        assert out.shape == (S, cfg.d_model)
        # all values are 1 → attention output must be exactly 1 everywhere
        np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-6)

    def test_causality(self, tiny):
        """Changing a future token must not affect past logits."""
        cfg, _, rom = tiny
        a = jnp.asarray([1, 2, 3, 4], jnp.int32)
        b = jnp.asarray([1, 2, 3, 200], jnp.int32)
        kc, vc = M.empty_caches(cfg)
        la, _, _ = M.full_fwd(rom, cfg, a, jnp.arange(4), kc, vc)
        kc, vc = M.empty_caches(cfg)
        lb, _, _ = M.full_fwd(rom, cfg, b, jnp.arange(4), kc, vc)
        np.testing.assert_allclose(
            np.asarray(la[:3]), np.asarray(lb[:3]), rtol=1e-5, atol=1e-5
        )
        assert not np.allclose(np.asarray(la[3]), np.asarray(lb[3]))

    def test_rope_rotation_preserves_norm(self, tiny):
        cfg, _, _ = tiny
        x = jnp.asarray(
            np.random.default_rng(0).normal(size=(6, cfg.n_heads, cfg.head_dim)),
            jnp.float32,
        )
        y = M.apply_rope(x, jnp.arange(6), cfg)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1),
            rtol=1e-5,
        )

    def test_rope_relative_property(self, tiny):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        cfg, _, _ = tiny
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(1, 1, cfg.head_dim)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, cfg.head_dim)), jnp.float32)

        def dot_at(m, n):
            qm = M.apply_rope(q, jnp.asarray([m]), cfg)
            kn = M.apply_rope(k, jnp.asarray([n]), cfg)
            return float(jnp.sum(qm * kn))

        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4


class TestPartitions:
    def test_partitioned_equals_monolithic(self, tiny):
        """Running partitions in sequence == full_fwd (the property the
        rust pipeline depends on)."""
        cfg, _, rom = tiny
        toks = jnp.asarray([10, 20, 30], jnp.int32)
        pos = jnp.arange(3)
        kc, vc = M.empty_caches(cfg)
        want, _, _ = M.full_fwd(rom, cfg, toks, pos, kc, vc)

        h = M.embed_fwd(rom, toks)
        L = cfg.layers_per_partition
        for p in range(cfg.n_partitions):
            kcp, vcp = M.empty_caches(cfg, L)
            h, _, _ = M.partition_fwd(rom, p, cfg, h, kcp, vcp, pos)
        got = M.head_fwd(rom, cfg, h, 2)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want[2]), rtol=2e-4, atol=2e-4
        )

    def test_head_fwd_row_selection(self, tiny):
        cfg, _, rom = tiny
        h = jnp.asarray(
            np.random.default_rng(1).normal(size=(4, cfg.d_model)), jnp.float32
        )
        for i in range(4):
            want = M.head_fwd(rom, cfg, h[i : i + 1], 0)
            got = M.head_fwd(rom, cfg, h, i)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


class TestLoRA:
    def make_lora(self, cfg, placement, rank=4, bits=6, seed=0):
        key = jax.random.PRNGKey(seed)
        layers = []
        for li in range(cfg.n_layers):
            layer = {}
            for name in placement:
                fan_in = cfg.d_ff if name == "down" else cfg.d_model
                if name in ("k", "v"):
                    fan_out = cfg.n_kv_heads * cfg.head_dim
                elif name in ("gate", "up"):
                    fan_out = cfg.d_ff
                elif name == "down":
                    fan_out = cfg.d_model
                else:
                    fan_out = cfg.d_model
                key, k1 = jax.random.split(key)
                layer[name] = {
                    "a": jax.random.normal(k1, (fan_in, rank)) * 0.05,
                    "b": jnp.zeros((rank, fan_out)),
                    "alpha": 2.0 * rank,
                    "rank": rank,
                    "bits": bits,
                }
            layers.append(layer)
        return {"layers": layers}

    def test_zero_b_adapter_is_noop(self, tiny):
        cfg, _, rom = tiny
        lora = self.make_lora(cfg, M.PAPER_PLACEMENT)
        toks = jnp.asarray([1, 2, 3], jnp.int32)
        kc, vc = M.empty_caches(cfg)
        base, _, _ = M.full_fwd(rom, cfg, toks, jnp.arange(3), kc, vc)
        kc, vc = M.empty_caches(cfg)
        adapted, _, _ = M.full_fwd(rom, cfg, toks, jnp.arange(3), kc, vc, lora=lora)
        np.testing.assert_allclose(np.asarray(base), np.asarray(adapted), rtol=1e-5, atol=1e-5)

    def test_nonzero_adapter_changes_output(self, tiny):
        cfg, _, rom = tiny
        lora = self.make_lora(cfg, M.PAPER_PLACEMENT)
        for layer in lora["layers"]:
            for name in layer:
                layer[name]["b"] = (
                    jnp.ones_like(layer[name]["b"]) * 0.1
                )
        toks = jnp.asarray([1, 2, 3], jnp.int32)
        kc, vc = M.empty_caches(cfg)
        base, _, _ = M.full_fwd(rom, cfg, toks, jnp.arange(3), kc, vc)
        kc, vc = M.empty_caches(cfg)
        adapted, _, _ = M.full_fwd(rom, cfg, toks, jnp.arange(3), kc, vc, lora=lora)
        assert not np.allclose(np.asarray(base), np.asarray(adapted))

    def test_paper_placement_param_overhead(self):
        """Table I claims ~0.2–0.3% extra parameters for rank 16 on
        (V, O, Down) — check the arithmetic on the real Falcon3-1B dims."""
        cfg = FALCON3_1B
        rank = 16
        kv_dim = cfg.n_kv_heads * cfg.head_dim
        extra = cfg.n_layers * (
            (cfg.d_model + kv_dim) * rank  # V
            + (cfg.d_model + cfg.d_model) * rank  # O
            + (cfg.d_ff + cfg.d_model) * rank  # Down
        )
        pct = 100.0 * extra / cfg.param_count()
        assert 0.15 < pct < 0.45  # paper: 0.30% for Falcon3-1B


class TestQuantPaths:
    def test_kernel_path_matches_jnp_path(self, tiny):
        cfg, _, rom = tiny
        toks = jnp.asarray([4, 5, 6, 7], jnp.int32)
        kc, vc = M.empty_caches(cfg)
        a, _, _ = M.full_fwd(rom, cfg, toks, jnp.arange(4), kc, vc, use_kernel=False)
        kc, vc = M.empty_caches(cfg)
        b, _, _ = M.full_fwd(rom, cfg, toks, jnp.arange(4), kc, vc, use_kernel=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    def test_train_path_differentiable(self, tiny):
        cfg, params, _ = tiny

        def loss(w):
            x = jnp.ones((2, cfg.d_model))
            return jnp.sum(M.bit_linear_train(x, w, cfg))

        g = jax.grad(loss)(params["layers"][0]["q"])
        assert float(jnp.max(jnp.abs(g))) > 0  # STE passes gradients

    def test_generate_greedy_deterministic(self, tiny):
        cfg, _, rom = tiny
        a = M.generate_greedy(rom, cfg, [1, 2, 3], 4)
        b = M.generate_greedy(rom, cfg, [1, 2, 3], 4)
        assert a == b
        assert all(0 <= t < cfg.vocab_size for t in a)


class TestActivationBits:
    """BitNet a4.8-style hybrid: the model must run with 4-bit
    activations (TriMLA single-pass mode) as well as 8-bit."""

    def test_int4_forward_runs_and_differs(self):
        from dataclasses import replace

        cfg8 = SIM_TINY
        cfg4 = replace(SIM_TINY, act_bits=4)
        params = M.init_params(cfg8, jax.random.PRNGKey(1))
        rom = M.rom_image(params, cfg8)
        toks = jnp.asarray([1, 2, 3], jnp.int32)
        kc, vc = M.empty_caches(cfg8)
        l8, _, _ = M.full_fwd(rom, cfg8, toks, jnp.arange(3), kc, vc)
        kc, vc = M.empty_caches(cfg4)
        l4, _, _ = M.full_fwd(rom, cfg4, toks, jnp.arange(3), kc, vc)
        assert l4.shape == l8.shape
        # coarser activations → different (but finite) logits
        assert not np.allclose(np.asarray(l4), np.asarray(l8))
        assert np.all(np.isfinite(np.asarray(l4)))

    def test_int4_kernel_path_matches_jnp_path(self):
        from dataclasses import replace

        cfg4 = replace(SIM_TINY, act_bits=4)
        params = M.init_params(cfg4, jax.random.PRNGKey(2))
        rom = M.rom_image(params, cfg4)
        toks = jnp.asarray([7, 8], jnp.int32)
        kc, vc = M.empty_caches(cfg4)
        a, _, _ = M.full_fwd(rom, cfg4, toks, jnp.arange(2), kc, vc, use_kernel=False)
        kc, vc = M.empty_caches(cfg4)
        b, _, _ = M.full_fwd(rom, cfg4, toks, jnp.arange(2), kc, vc, use_kernel=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


class TestFullPrecisionPath:
    """qat=False raw-float path (the Fig 6(b) comparator)."""

    def test_fp_differs_from_qat(self):
        cfg = SIM_TINY
        params = M.init_params(cfg, jax.random.PRNGKey(3))
        toks = jnp.asarray([4, 5, 6], jnp.int32)
        kc, vc = M.empty_caches(cfg)
        fp, _, _ = M.full_fwd(params, cfg, toks, jnp.arange(3), kc, vc, qat=False)
        kc, vc = M.empty_caches(cfg)
        qat, _, _ = M.full_fwd(params, cfg, toks, jnp.arange(3), kc, vc, train=True, qat=True)
        assert not np.allclose(np.asarray(fp), np.asarray(qat))

    def test_fp_is_differentiable(self):
        cfg = SIM_TINY
        params = M.init_params(cfg, jax.random.PRNGKey(4))

        def loss(p):
            kc, vc = M.empty_caches(cfg)
            logits, _, _ = M.full_fwd(
                p, cfg, jnp.asarray([1, 2], jnp.int32), jnp.arange(2), kc, vc, qat=False
            )
            return jnp.sum(logits**2)

        g = jax.grad(loss)(params)
        gmax = max(float(jnp.abs(x).max()) for x in jax.tree_util.tree_leaves(g))
        assert gmax > 0
