"""Quantizer properties — the numeric contracts the ROM image relies on."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant


def arrays(draw, shape, lo=-4.0, hi=4.0):
    vals = draw(
        st.lists(
            st.floats(lo, hi, allow_nan=False, width=32),
            min_size=int(np.prod(shape)),
            max_size=int(np.prod(shape)),
        )
    )
    return np.asarray(vals, np.float32).reshape(shape)


@st.composite
def matrices(draw, max_dim=24):
    r = draw(st.integers(1, max_dim))
    c = draw(st.integers(1, max_dim))
    return arrays(draw, (r, c))


class TestAbsmeanTernary:
    @settings(max_examples=50, deadline=None)
    @given(matrices())
    def test_values_are_ternary(self, w):
        w_q, scale = quant.absmean_ternary(jnp.asarray(w))
        vals = np.unique(np.asarray(w_q))
        assert set(vals.tolist()) <= {-1.0, 0.0, 1.0}
        assert float(scale) > 0

    def test_scale_is_absmean(self):
        w = jnp.asarray([[1.0, -2.0], [3.0, -4.0]])
        _, scale = quant.absmean_ternary(w)
        assert abs(float(scale) - 2.5) < 1e-6

    def test_zero_matrix_maps_to_zero(self):
        w_q, _ = quant.absmean_ternary(jnp.zeros((4, 4)))
        assert float(jnp.max(jnp.abs(w_q))) == 0.0

    def test_large_magnitudes_saturate(self):
        w = jnp.asarray([[100.0, -100.0, 0.001, 0.0]])
        w_q, _ = quant.absmean_ternary(w)
        assert np.asarray(w_q).tolist() == [[1.0, -1.0, 0.0, 0.0]]

    @settings(max_examples=30, deadline=None)
    @given(matrices())
    def test_sign_preserved(self, w):
        w_q, _ = quant.absmean_ternary(jnp.asarray(w))
        wq = np.asarray(w_q)
        # wherever quantized nonzero, sign matches the original
        nz = wq != 0
        assert np.all(np.sign(wq[nz]) == np.sign(w[nz]))


class TestAbsmax:
    @settings(max_examples=50, deadline=None)
    @given(matrices(), st.sampled_from([4, 8]))
    def test_integer_range(self, x, bits):
        x_q, scale = quant.absmax_quantize(jnp.asarray(x), bits)
        q = np.asarray(x_q)
        qmax = 2 ** (bits - 1) - 1
        assert np.all(np.abs(q) <= qmax)
        assert np.allclose(q, np.round(q))  # exact integers

    @settings(max_examples=50, deadline=None)
    @given(matrices(), st.sampled_from([4, 8]))
    def test_reconstruction_error_bound(self, x, bits):
        xj = jnp.asarray(x)
        x_q, scale = quant.absmax_quantize(xj, bits)
        err = np.abs(np.asarray(x_q * scale) - x)
        # half-step bound per row
        assert np.all(err <= np.asarray(scale) * 0.5 + 1e-6)

    def test_per_row_scales(self):
        x = jnp.asarray([[1.0, 0.5], [100.0, 50.0]])
        _, scale = quant.absmax_int8(x)
        assert scale.shape == (2, 1)
        assert float(scale[1, 0]) > float(scale[0, 0])

    def test_int4_coarser_than_int8(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)
        e8 = float(jnp.mean(jnp.abs(quant.fake_quant(x, 8) - x)))
        e4 = float(jnp.mean(jnp.abs(quant.fake_quant(x, 4) - x)))
        assert e4 > e8


class TestKbit:
    @settings(max_examples=30, deadline=None)
    @given(matrices(), st.integers(2, 8))
    def test_levels(self, w, bits):
        w_q, _ = quant.quantize_kbit(jnp.asarray(w), bits)
        q = np.asarray(w_q)
        qmax = 2 ** (bits - 1) - 1
        assert np.all(np.abs(q) <= qmax)
        assert np.allclose(q, np.round(q))

    def test_fake_quant_tensor_idempotent_on_levels(self):
        w = jnp.asarray([[1.0, -1.0, 0.5]])
        fq = quant.fake_quant_tensor(w, 6)
        fq2 = quant.fake_quant_tensor(fq, 6)
        assert np.allclose(np.asarray(fq), np.asarray(fq2), atol=1e-6)


class TestTritPacking:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.sampled_from([-1.0, 0.0, 1.0]), min_size=2, max_size=64))
    def test_roundtrip(self, trits):
        if len(trits) % 2:
            trits = trits + [0.0]
        w = jnp.asarray(trits, jnp.float32)
        packed = quant.pack_trits_base3(w)
        assert packed.dtype == jnp.uint8
        assert int(jnp.max(packed)) <= 8
        back = quant.unpack_trits_base3(packed)
        assert np.array_equal(np.asarray(back), np.asarray(w))

    def test_density_two_trits_per_cell(self):
        w = jnp.asarray([1.0, -1.0] * 8)
        packed = quant.pack_trits_base3(w)
        assert packed.shape[0] == w.shape[0] // 2

    def test_sparsity_measure(self):
        w = jnp.asarray([0.0, 0.0, 1.0, -1.0])
        assert float(quant.ternary_sparsity(w)) == 0.5
