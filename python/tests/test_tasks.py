"""Synthetic task suite + metric implementations."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tasks as T


RNG = np.random.default_rng(0)


class TestGenerators:
    @pytest.mark.parametrize("task", list(T.TASKS))
    def test_examples_well_formed(self, task):
        rng = np.random.default_rng(1)
        for _ in range(20):
            ex = T.TASKS[task](rng)
            assert ex.tokens.dtype == np.int32
            assert ex.tokens.shape == ex.loss_mask.shape
            assert ex.tokens[0] == T.BOS
            assert ex.tokens[-1] == T.EOS
            assert np.all(ex.tokens >= 0) and np.all(ex.tokens < 256)
            if task != "lm":
                assert ex.loss_mask.sum() >= 1
                assert len(ex.answer) >= 1

    def test_qa_answer_is_recoverable(self):
        """The queried value must actually appear bound to the queried
        key in the context."""
        rng = np.random.default_rng(2)
        for _ in range(50):
            ex = T.qa_example(rng)
            toks = ex.tokens.tolist()
            sep = toks.index(T.SEP)
            qkey = toks[sep + 1]
            ctx = toks[1:sep]
            pairs = {ctx[i]: ctx[i + 1] for i in range(0, len(ctx), 2)}
            assert pairs[qkey] == ex.answer[0]

    def test_summarization_keeps_marked_words_in_order(self):
        rng = np.random.default_rng(3)
        for _ in range(30):
            ex = T.summarization_example(rng)
            toks = ex.tokens.tolist()
            sep = toks.index(T.SEP)
            body = toks[1:sep]
            # every answer token follows a noise marker in the body
            marked = [body[i + 1] for i, t in enumerate(body[:-1]) if t in T.NOISE]
            assert marked == ex.answer

    def test_drop_count_is_correct(self):
        rng = np.random.default_rng(4)
        for _ in range(30):
            ex = T.drop_example(rng)
            toks = ex.tokens.tolist()
            sep = toks.index(T.SEP)
            target = toks[sep + 1]
            passage = toks[1:sep]
            count = passage.count(target)
            assert ex.answer == [T.DIGITS[count]]

    def test_batch_padding(self):
        rng = np.random.default_rng(5)
        toks, mask, exs = T.batch(rng, "qa", 8, 48)
        assert toks.shape == (8, 48)
        assert mask.shape == (8, 48)
        assert len(exs) == 8
        # padding area has zero mask
        for i, ex in enumerate(exs):
            assert mask[i, len(ex.tokens):].sum() == 0


class TestMetrics:
    def test_exact_match(self):
        assert T.exact_match([1, 2], [1, 2]) == 1.0
        assert T.exact_match([1, 2], [2, 1]) == 0.0
        assert T.exact_match([], []) == 1.0

    def test_f1_perfect_and_disjoint(self):
        assert T.f1_score([1, 2, 3], [1, 2, 3]) == 1.0
        assert T.f1_score([1, 2], [3, 4]) == 0.0

    def test_f1_partial(self):
        # pred {1,2}, ref {2,3}: p=r=0.5 → f1=0.5
        assert abs(T.f1_score([1, 2], [2, 3]) - 0.5) < 1e-9

    def test_f1_respects_multiplicity(self):
        assert T.f1_score([7, 7], [7]) == pytest.approx(2 / 3)

    def test_rouge_l_order_sensitivity(self):
        # same unigrams, different order: ROUGE-1 identical, ROUGE-L drops
        ref = [1, 2, 3, 4]
        shuffled = [4, 3, 2, 1]
        assert T.rouge_1(shuffled, ref) == 1.0
        assert T.rouge_l(shuffled, ref) < 0.5

    def test_rouge_l_subsequence(self):
        # pred = subsequence of ref: recall = 2/4, precision = 1
        assert T.rouge_l([1, 3], [1, 2, 3, 4]) == pytest.approx(2 * 0.5 / 1.5)

    @settings(max_examples=100, deadline=None)
    @given(
        st.lists(st.integers(0, 10), max_size=8),
        st.lists(st.integers(0, 10), max_size=8),
    )
    def test_metric_ranges(self, a, b):
        for fn in [T.exact_match, T.f1_score, T.rouge_1, T.rouge_l]:
            v = fn(a, b)
            assert 0.0 <= v <= 1.0
            # symmetry of F1-style metrics in perfect case
        if a == b:
            assert T.f1_score(a, b) == 1.0
            assert T.rouge_l(a, b) == 1.0
