"""AOT export path: HLO text integrity + manifest schema.

Uses a 2-partition miniature config so the full lowering runs in
seconds; the real artifact set is exercised by the rust integration
tests against `artifacts/`.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile.configs import ModelConfig

MINI = ModelConfig(
    name="mini",
    n_layers=2,
    d_model=32,
    n_heads=2,
    n_kv_heads=1,
    d_ff=64,
    vocab_size=64,
    max_seq=16,
    n_partitions=2,
)


@pytest.fixture(scope="module")
def lowered():
    rom = aot.build_rom(MINI, seed=1, trained_npz=None)
    return rom, aot.lower_all(MINI, rom, prefill=8, use_kernel=True)


class TestHloText:
    def test_all_entry_points_present(self, lowered):
        _, texts = lowered
        expected = {
            "embed_prefill", "embed_decode", "head_prefill", "head_decode",
            "part0_prefill", "part0_decode", "part1_prefill", "part1_decode",
            "full_prefill", "full_decode",
        }
        assert set(texts) == expected

    def test_no_elided_constants(self, lowered):
        """The classic failure mode: the HLO printer replacing weight
        constants with `{...}` would silently destroy the ROM."""
        _, texts = lowered
        for name, text in texts.items():
            assert "constant({...}" not in text.replace(" ", ""), name

    def test_weights_are_baked_not_parameters(self, lowered):
        """ROM property: partition executables take only (h, k, v[, pos])
        as parameters — no weight tensors cross the interface."""
        _, texts = lowered
        text = texts["part0_decode"]
        entry = text[text.index("ENTRY") :]
        n_params = entry.count("parameter(")
        assert n_params == 4, f"expected 4 runtime params, found {n_params}"
        # and the weight bytes dominate the artifact size
        assert len(text) > 50_000

    def test_prefill_parameter_shapes(self, lowered):
        _, texts = lowered
        entry = texts["part0_prefill"]
        entry = entry[entry.index("ENTRY") :]
        assert "f32[8,32]" in entry  # h: [prefill, d_model]
        assert "f32[1,16,1,16]" in entry  # caches: [L,T,KV,hd]

    def test_deterministic_lowering(self):
        rom = aot.build_rom(MINI, seed=1, trained_npz=None)
        a = aot.lower_all(MINI, rom, prefill=8, use_kernel=False)
        b = aot.lower_all(MINI, rom, prefill=8, use_kernel=False)
        assert a["part0_decode"] == b["part0_decode"]


class TestGolden:
    def test_golden_trace_schema(self, lowered):
        rom, _ = lowered
        g = aot.golden_trace(MINI, rom)
        assert len(g["generated"]) == aot.GOLDEN_NEW_TOKENS
        assert len(g["prefill_last_logits"]) == MINI.vocab_size
        assert all(0 <= t < MINI.vocab_size for t in g["generated"])

    def test_golden_is_reproducible(self, lowered):
        rom, _ = lowered
        assert aot.golden_trace(MINI, rom) == aot.golden_trace(MINI, rom)


class TestParamsRoundtrip:
    def test_flatten_unflatten(self):
        import numpy as np

        params = M.init_params(MINI, jax.random.PRNGKey(3))
        flat = aot.flatten_params(params)
        back = aot.unflatten_params(MINI, {k: np.asarray(v) for k, v in flat.items()})
        for a, b in zip(
            jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(back)
        ):
            assert jnp.allclose(a, b)


class TestRealManifest:
    """Checks against the actual build artifacts when present."""

    def test_manifest_consistency(self):
        path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        m = json.load(open(path))
        assert m["config"]["n_partitions"] == 6
        assert len(m["artifacts"]) >= 16
        for name, info in m["artifacts"].items():
            f = os.path.join(os.path.dirname(path), info["file"])
            assert os.path.exists(f), name
            assert os.path.getsize(f) == info["bytes"]
