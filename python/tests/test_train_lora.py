"""Adaptation machinery: optimizer, adapter plumbing, loss masking.

Training *quality* is exercised by `make experiments`; these tests pin
the machinery (shapes, gradients, masking, requantization) at a few
seconds of runtime.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tasks as T
from compile import train_lora as TL
from compile.configs import ModelConfig

MINI = ModelConfig(
    name="mini",
    n_layers=2,
    d_model=32,
    n_heads=2,
    n_kv_heads=1,
    d_ff=64,
    vocab_size=256,
    max_seq=48,
    n_partitions=2,
)


class TestAdam:
    def test_step_moves_params_against_gradient(self):
        params = {"w": jnp.ones((4,))}
        grads = {"w": jnp.ones((4,))}
        st = TL.adam_init(params)
        new, st2 = TL.adam_step(params, grads, st, lr=0.1)
        assert np.all(np.asarray(new["w"]) < 1.0)
        assert st2["t"] == 1

    def test_zero_grad_is_noop(self):
        params = {"w": jnp.ones((4,))}
        grads = {"w": jnp.zeros((4,))}
        new, _ = TL.adam_step(params, grads, TL.adam_init(params), lr=0.1)
        np.testing.assert_allclose(np.asarray(new["w"]), 1.0)


class TestLoraPlumbing:
    def test_init_shapes(self):
        lora = TL.init_lora(MINI, ["v", "o", "down"], rank=4, bits=6, seed=0)
        assert len(lora["layers"]) == MINI.n_layers
        l0 = lora["layers"][0]
        assert l0["v"]["a"].shape == (32, 4)
        assert l0["v"]["b"].shape == (4, MINI.n_kv_heads * MINI.head_dim)
        assert l0["down"]["a"].shape == (64, 4)
        assert l0["down"]["b"].shape == (4, 32)
        # B init to zero → adapter starts as a no-op
        assert float(jnp.abs(l0["o"]["b"]).max()) == 0.0

    def test_trainable_roundtrip(self):
        lora = TL.init_lora(MINI, ["v"], rank=2, bits=6, seed=1)
        tr = TL.lora_trainable(lora)
        tr[0]["v"]["b"] = jnp.ones_like(tr[0]["v"]["b"])
        lora2 = TL.lora_with(lora, tr)
        assert float(lora2["layers"][0]["v"]["b"].min()) == 1.0
        # metadata preserved
        assert lora2["layers"][0]["v"]["bits"] == 6

    def test_requant_changes_bits_only(self):
        lora = TL.init_lora(MINI, ["v"], rank=2, bits=6, seed=1)
        l2 = TL.json_safe_requant(lora, 3)
        assert l2["layers"][0]["v"]["bits"] == 3
        np.testing.assert_array_equal(
            np.asarray(l2["layers"][0]["v"]["a"]),
            np.asarray(lora["layers"][0]["v"]["a"]),
        )


class TestLossAndTraining:
    @pytest.fixture(scope="class")
    def rom(self):
        params = M.init_params(MINI, jax.random.PRNGKey(0))
        return M.rom_image(params, MINI)

    def test_loss_respects_mask(self, rom):
        rng = np.random.default_rng(0)
        toks, mask, _ = T.batch(rng, "qa", 4, 48)
        full = TL.batched_loss(rom, MINI, jnp.asarray(toks), jnp.ones_like(jnp.asarray(mask)))
        masked = TL.batched_loss(rom, MINI, jnp.asarray(toks), jnp.asarray(mask))
        assert float(full) != float(masked)
        # all-zero mask → zero loss (normalized by max(weight,1))
        zero = TL.batched_loss(rom, MINI, jnp.asarray(toks), jnp.zeros_like(jnp.asarray(mask)))
        assert float(zero) == 0.0

    def test_lora_gradients_flow(self, rom):
        lora = TL.init_lora(MINI, ["v", "down"], rank=2, bits=6, seed=2)
        rng = np.random.default_rng(1)
        toks, mask, _ = T.batch(rng, "qa", 4, 48)

        def loss_fn(tr):
            return TL.batched_loss(
                rom, MINI, jnp.asarray(toks), jnp.asarray(mask),
                lora=TL.lora_with(lora, tr), train=True,
            )

        grads = jax.grad(loss_fn)(TL.lora_trainable(lora))
        # B starts at zero, so dL/dA is zero on the first step but dL/dB
        # is not (the standard LoRA init property)
        gb = float(jnp.abs(grads[0]["v"]["b"]).max())
        assert gb > 0.0, "no gradient reached the adapter"

    def test_one_training_step_reduces_loss(self, rom):
        lora = TL.init_lora(MINI, ["v", "o", "down"], rank=4, bits=6, seed=3)
        rng = np.random.default_rng(2)
        toks, mask, _ = T.batch(rng, "qa", 8, 48)
        tj, mj = jnp.asarray(toks), jnp.asarray(mask)

        before = TL.batched_loss(rom, MINI, tj, mj, lora=lora, train=True)
        trained = TL.train_lora(
            rom, MINI, lora, "qa", steps=12, batch_size=8, seed=2, lr=5e-2
        )
        after = TL.batched_loss(rom, MINI, tj, mj, lora=trained, train=True)
        assert float(after) < float(before), (float(before), float(after))

    def test_eval_task_returns_metrics(self, rom):
        sc = TL.eval_task(rom, MINI, "qa", n_examples=4)
        assert set(sc) == {"em", "f1"}
        assert all(0.0 <= v <= 100.0 for v in sc.values())
        sc = TL.eval_task(rom, MINI, "summarization", n_examples=4)
        assert set(sc) == {"rouge1", "rougeL"}

    def test_eval_ppl_positive(self, rom):
        ppl = TL.eval_ppl(rom, MINI, n_batches=1, batch_size=4)
        assert ppl > 1.0
