"""L1 Pallas kernel: the BitROM macro MAC (ternary-weight matmul).

Hardware mapping (DESIGN.md §2 — Hardware-Adaptation):

* The BiROMA weight block for the current grid step is resident in VMEM —
  VMEM plays the role of the precharged bitlines feeding the TriMLAs.
* TriMLA's three modes (add / subtract / skip, selected by the two
  comparator bits in paper Fig 4) appear as the positive/negative weight
  masks: the positive lane *adds* the activation, the negative lane
  *subtracts* it, and the zero lane contributes nothing. The datapath is
  adder-only — no multiplier is ever applied to a weight, exactly like
  the silicon.
* The local-then-global accumulation schedule is the grid's k-loop: each
  k-step produces a local partial (TriMLA outputs for one column group),
  accumulated into the output block; the final k-step applies the scales
  — the "one-shot global adder tree" pass.
* 8-bit activations use the two-cycle bit-serial mode: the int8 value is
  split into 4-bit digits processed through the same 4-bit datapath with
  shift-and-accumulate (``bit_serial=True``).

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO. Real-TPU expectations
(VMEM footprint, MXU utilization) are estimated in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes — chosen for TPU VMEM budget (see EXPERIMENTS.md
# §Perf L1): (128, 128, 128) f32 blocks = 3 * 64 KiB << 16 MiB VMEM,
# MXU-aligned (128 lanes).
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, *, n_k: int, bit_serial: bool):
    """One (m, n, k) grid step of the macro MAC."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)

    # TriMLA mode decode (paper Fig 4 truth table): MSB comparator != 0
    # gates the accumulator (zero-skip); LSB comparator picks add vs sub.
    w_pos = (w > 0.0).astype(jnp.float32)
    w_neg = (w < 0.0).astype(jnp.float32)

    def adder_pass(act):
        # adder-only datapath: + for '+1' cells, - for '-1' cells, zero
        # cells are skipped (contribute no energy, no term).
        pos = jax.lax.dot(act, w_pos, preferred_element_type=jnp.float32)
        neg = jax.lax.dot(act, w_neg, preferred_element_type=jnp.float32)
        return pos - neg

    if bit_serial:
        # two-cycle 4-bit bit-serial processing of int8 activations
        hi = jnp.floor(x / 16.0)
        lo = x - hi * 16.0
        local = 16.0 * adder_pass(hi) + adder_pass(lo)
    else:
        local = adder_pass(x)

    o_ref[...] += local

    @pl.when(k_idx == n_k - 1)
    def _dequant():
        # global pass complete: apply activation (per-row) and weight
        # (per-tensor) scales to leave f32 results.
        o_ref[...] *= xs_ref[...] * ws_ref[0, 0]


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "bit_serial", "interpret"),
)
def ternary_matmul(
    x_q,
    w_q,
    x_scale,
    w_scale,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    bit_serial: bool = False,
    interpret: bool = True,
):
    """``y = (x_q @ w_q) * x_scale * w_scale`` with ternary ``w_q``.

    Args:
      x_q: [m, k] quantized activations — exact integers in a float
        container (int8 range, or int4 for the a4.8 hybrid).
      w_q: [k, n] ternary weights, exact {-1, 0, +1} in a float container
        (the ROM contents).
      x_scale: [m, 1] per-token activation scales.
      w_scale: scalar (or [1, 1]) per-tensor weight scale.
      bit_serial: process int8 activations as two 4-bit digits (the
        hardware's two-cycle mode). Numerically identical; exercised by
        tests to pin the digit decomposition.

    Returns: [m, n] f32.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)

    w_scale = jnp.asarray(w_scale, jnp.float32).reshape(1, 1)
    x_scale = jnp.asarray(x_scale, jnp.float32).reshape(m, 1)

    block_m = min(block_m, m) if m % block_m else block_m
    xp = _pad_to(_pad_to(x_q.astype(jnp.float32), block_m, 0), block_k, 1)
    sp = _pad_to(x_scale, block_m, 0)
    wp = _pad_to(_pad_to(w_q.astype(jnp.float32), block_k, 0), block_n, 1)
    mp, kp = xp.shape
    _, np_ = wp.shape
    n_k = kp // block_k

    grid = (mp // block_m, np_ // block_n, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, bit_serial=bit_serial),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, sp, w_scale)
    return out[:m, :n]


def vmem_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """Estimated VMEM working set for one grid step (f32): x block +
    w block + output block + the two weight masks the compiler
    materializes. Used by the L1 perf study (EXPERIMENTS.md §Perf)."""
    f = 4
    return f * (
        block_m * block_k  # x
        + 3 * block_k * block_n  # w + two masks
        + block_m * block_n  # out
        + block_m  # scales
    )
