"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package must match its oracle bit-for-bit in f32 (the hypothesis sweeps
in ``python/tests/test_kernels.py`` enforce allclose at tight tolerance
across shapes, sparsities and activation bit-widths).
"""

import jax.numpy as jnp


def ternary_matmul_ref(x_q, w_q, x_scale, w_scale):
    """Reference for the BitROM macro MAC: ``y = (x_q @ w_q) * scales``.

    ``x_q``: [m, k] exact integers (float container), per-row scales
    ``x_scale``: [m, 1]; ``w_q``: [k, n] exact {-1,0,+1}; ``w_scale``:
    scalar. Accumulation in f32 (exact for the integer ranges involved:
    |acc| <= k * 127 < 2^24 for k < 2^17).
    """
    acc = jnp.dot(x_q.astype(jnp.float32), w_q.astype(jnp.float32))
    return acc * x_scale * w_scale


def ternary_matmul_local_global_ref(x_q, w_q, x_scale, w_scale, group: int = 8):
    """Local-then-global accumulation order (paper Fig 3): columns of the
    BiROMA are processed in groups of ``group`` by a TriMLA (local,
    sequential adds/subs with zero-skip), then a single adder-tree pass
    sums the TriMLA partials. Numerically identical to
    :func:`ternary_matmul_ref` in exact integer arithmetic — this oracle
    exists to pin the *associativity order* the hardware uses, so the
    rust `ciROM::Macro` and the Pallas kernel can both be checked against
    the same grouping.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    pad = (-k) % group
    if pad:
        x_q = jnp.pad(x_q, ((0, 0), (0, pad)))
        w_q = jnp.pad(w_q, ((0, pad), (0, 0)))
        k += pad
    xg = x_q.reshape(m, k // group, group).astype(jnp.float32)
    wg = w_q.reshape(k // group, group, n).astype(jnp.float32)
    # local: per-group partial sums (TriMLA outputs) …
    partial = jnp.einsum("mgc,gcn->mgn", xg, wg)
    # … global: one-shot adder tree across groups.
    acc = jnp.sum(partial, axis=1)
    return acc * x_scale * w_scale


def bit_serial_split(x_q):
    """Split int8 integer values (float container) into two 4-bit digits:
    ``x = 16*hi + lo`` with ``lo`` in [0, 15] and ``hi`` in [-8, 8].

    This is TriMLA's two-cycle bit-serial mode for 8-bit activations
    (paper §III-B3): 4-bit datapath, shift-and-accumulate across cycles.
    """
    hi = jnp.floor(x_q / 16.0)
    lo = x_q - hi * 16.0
    return hi, lo


def ternary_matmul_bitserial_ref(x_q, w_q, x_scale, w_scale):
    """Two-cycle bit-serial reference: y = (16*(hi@W) + lo@W) * scales."""
    hi, lo = bit_serial_split(x_q)
    w = w_q.astype(jnp.float32)
    acc = 16.0 * jnp.dot(hi, w) + jnp.dot(lo, w)
    return acc * x_scale * w_scale


def lora_ref(x, a, b, alpha: float, rank: int):
    """Reference LoRA delta: ``dy = (x @ A) @ B * (alpha / rank)``.

    ``a``: [k, r], ``b``: [r, n]. The hardware realization is the paper's
    4-input multiplier-adder unit attached to each BitROM macro — a tiny
    dense MAC since r=16 << k.
    """
    return jnp.dot(jnp.dot(x, a), b) * (alpha / rank)
