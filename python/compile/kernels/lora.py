"""L1 Pallas kernel: the LoRA domain-adapter MAC.

The paper attaches a "simple 4-input multiplier-and-adder unit" to each
BitROM macro (§III-C): a tiny dense MAC is enough because the adapter is
rank-16 against channel dimensions of 2048–8192 (0.7% of the projection's
ops). The kernel computes the low-rank delta

    dy = (x @ A) @ B * (alpha / rank)

with A, B held in k-bit quantized form (paper: 6-bit) — dequantized on
the fly, exactly like the digital adapter reads its small SRAM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128


def _kernel(x_ref, a_ref, b_ref, sc_ref, o_ref, *, alpha_over_rank: float):
    x = x_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32) * sc_ref[0, 0]  # dequant A
    b = b_ref[...].astype(jnp.float32) * sc_ref[0, 1]  # dequant B
    xa = jax.lax.dot(x, a, preferred_element_type=jnp.float32)
    o_ref[...] = (
        jax.lax.dot(xa, b, preferred_element_type=jnp.float32) * alpha_over_rank
    )


@functools.partial(
    jax.jit, static_argnames=("alpha", "rank", "block_m", "interpret")
)
def lora_delta(
    x,
    a_q,
    b_q,
    a_scale,
    b_scale,
    *,
    alpha: float,
    rank: int,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = True,
):
    """LoRA delta with quantized adapters.

    Args:
      x: [m, k] activations (already int8-fake-quantized upstream — the
        paper keeps adapter activations at 8 bits).
      a_q: [k, r] quantized A (exact integers, float container).
      b_q: [r, n] quantized B.
      a_scale, b_scale: per-tensor dequant scales.

    Returns: [m, n] f32 delta to add to the frozen BitLinear output.
    """
    m, k = x.shape
    k2, r = a_q.shape
    r2, n = b_q.shape
    assert k == k2 and r == r2 == rank, (x.shape, a_q.shape, b_q.shape, rank)

    scales = jnp.array(
        [[jnp.float32(a_scale), jnp.float32(b_scale)]], jnp.float32
    ).reshape(1, 2)

    bm = min(block_m, m)
    pad = (-m) % bm
    xp = jnp.pad(x.astype(jnp.float32), ((0, pad), (0, 0))) if pad else x.astype(jnp.float32)
    mp = xp.shape[0]

    out = pl.pallas_call(
        functools.partial(_kernel, alpha_over_rank=alpha / rank),
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, r), lambda i: (0, 0)),
            pl.BlockSpec((r, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=interpret,
    )(xp, a_q.astype(jnp.float32), b_q.astype(jnp.float32), scales)
    return out[:m]
