"""Adaptation experiments (build-time): regenerates the paper's
Table I, Table II, Fig 6(a) and Fig 6(b) on the scaled-down model +
synthetic-task substitutions documented in DESIGN.md §5.

Pipeline per base model (BitNet-QAT or full-precision):
  1. train the base on the generic LM corpus (the "pretraining");
  2. freeze it (BitNet → ternary ROM image);
  3. train rank-r LoRA adapters per task / placement / bit-width;
  4. evaluate base vs adapted with the paper's metrics.

Outputs `results/adaptation.json` (rendered by the rust
`adaptation_report` example) and `results/base_model.npz` (used by
aot.py as ROM contents so the served model is a *trained* one).

Runtime budget: every training run is a few hundred steps of a ~1M-param
model — the full study completes in minutes on CPU.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import tasks as T
from .configs import get_config

PAD_TO = 48


# ---------------------------------------------------------------------------
# Generic training machinery (tiny Adam, pure jax)
# ---------------------------------------------------------------------------


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


def batched_loss(params_or_rom, cfg, toks, mask, lora=None, train=True, qat=True):
    """Masked next-token cross-entropy over a [B, S] batch."""

    def one(seq, m):
        S = seq.shape[0]
        kc, vc = M.empty_caches(cfg)
        logits, _, _ = M.full_fwd(
            params_or_rom, cfg, seq, jnp.arange(S), kc, vc, lora=lora, train=train,
            qat=qat,
        )
        logp = jax.nn.log_softmax(logits[:-1], axis=-1)
        tgt = seq[1:]
        nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
        w = m[:-1]
        return jnp.sum(nll * w), jnp.sum(w)

    losses, weights = jax.vmap(one)(toks, mask)
    return jnp.sum(losses) / jnp.maximum(jnp.sum(weights), 1.0)


def train_base(cfg, *, bitnet: bool, steps: int, batch_size: int, seed: int, lr=2e-3):
    """Pretrain a base model on the generic LM corpus. `bitnet=True`
    applies ternary-weight QAT (the STE path); `False` trains full
    precision (the Fig 6(b) comparator)."""
    rng = np.random.default_rng(seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))

    # mixture: mostly LM corpus plus a small share of each downstream
    # format so the base model knows the task *syntax* but stays weak on
    # the tasks themselves (mirrors generic pretraining; adapters then
    # have real headroom — the Table I setting).
    def make_batch():
        task = rng.choice(["lm"] * 9 + ["qa", "summarization", "drop"])
        toks, mask, _ = T.batch(rng, task, batch_size, PAD_TO)
        return jnp.asarray(toks), jnp.asarray(mask)

    @jax.jit
    def step_fn(params, opt_m, opt_v, opt_t, toks, mask):
        def loss_fn(p):
            return batched_loss(p, cfg, toks, mask, train=True, qat=bitnet)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new, st = adam_step(params, grads, {"m": opt_m, "v": opt_v, "t": opt_t}, lr)
        return new, st["m"], st["v"], st["t"], loss

    opt = adam_init(params)
    m, v, t = opt["m"], opt["v"], opt["t"]
    losses = []
    for i in range(steps):
        toks, mask = make_batch()
        params, m, v, t, loss = step_fn(params, m, v, t, toks, mask)
        losses.append(float(loss))
    return params, losses


def init_lora(cfg, placement, rank, bits, seed, alpha=None):
    key = jax.random.PRNGKey(seed)
    layers = []
    for _ in range(cfg.n_layers):
        layer = {}
        for name in placement:
            fan_in = cfg.d_ff if name == "down" else cfg.d_model
            if name in ("k", "v"):
                fan_out = cfg.n_kv_heads * cfg.head_dim
            elif name in ("gate", "up"):
                fan_out = cfg.d_ff
            elif name == "down":
                fan_out = cfg.d_model
            else:
                fan_out = cfg.d_model
            key, k1 = jax.random.split(key)
            layer[name] = {
                "a": jax.random.normal(k1, (fan_in, rank)) * (fan_in**-0.5),
                "b": jnp.zeros((rank, fan_out)),
                "alpha": float(alpha if alpha is not None else 2 * rank),
                "rank": rank,
                "bits": bits,
            }
        layers.append(layer)
    return {"layers": layers}


def lora_trainable(lora):
    """Extract the trainable (a, b) leaves as a pytree."""
    return [
        {name: {"a": ad["a"], "b": ad["b"]} for name, ad in layer.items()}
        for layer in lora["layers"]
    ]


def lora_with(lora, trainable):
    out = {"layers": []}
    for layer, tl in zip(lora["layers"], trainable):
        nl = {}
        for name, ad in layer.items():
            nl[name] = dict(ad)
            nl[name]["a"] = tl[name]["a"]
            nl[name]["b"] = tl[name]["b"]
        out["layers"].append(nl)
    return out


def train_lora(rom_or_params, cfg, lora, task, *, steps, batch_size, seed, lr=5e-3,
               qat=True):
    """Train adapters against a frozen base on one task. ``qat=False``
    marks a raw-float full-precision base (Fig 6(b) comparator)."""
    rng = np.random.default_rng(seed)

    @jax.jit
    def step_fn(trainable, opt_m, opt_v, opt_t, toks, mask):
        def loss_fn(tr):
            return batched_loss(
                rom_or_params, cfg, toks, mask, lora=lora_with(lora, tr),
                train=True, qat=qat,
            )

        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        new, st = adam_step(trainable, grads, {"m": opt_m, "v": opt_v, "t": opt_t}, lr)
        return new, st["m"], st["v"], st["t"], loss

    trainable = lora_trainable(lora)
    opt = adam_init(trainable)
    m, v, t = opt["m"], opt["v"], opt["t"]
    for _ in range(steps):
        toks, mask, _ = T.batch(rng, task, batch_size, PAD_TO)
        trainable, m, v, t, _ = step_fn(
            trainable, m, v, t, jnp.asarray(toks), jnp.asarray(mask)
        )
    return lora_with(lora, trainable)


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------


def eval_ppl(rom, cfg, *, lora=None, n_batches=8, batch_size=16, seed=99, train=False,
             qat=True):
    rng = np.random.default_rng(seed)
    tot, n = 0.0, 0
    for _ in range(n_batches):
        toks, mask, _ = T.batch(rng, "lm", batch_size, PAD_TO)
        loss = batched_loss(
            rom, cfg, jnp.asarray(toks), jnp.asarray(mask), lora=lora, train=train,
            qat=qat,
        )
        tot += float(loss)
        n += 1
    return float(np.exp(tot / n))


def eval_task(rom, cfg, task, *, lora=None, n_examples=64, seed=7, train=False,
              qat=True):
    """Greedy-decode the answer span and score with the task metrics."""
    rng = np.random.default_rng(seed)
    gen = T.TASKS[task]

    @jax.jit
    def logits_fn(toks):
        S = toks.shape[0]
        kc, vc = M.empty_caches(cfg)
        logits, _, _ = M.full_fwd(
            rom, cfg, toks, jnp.arange(S), kc, vc, lora=lora, train=train, qat=qat
        )
        return logits

    scores = {m: [] for m in T.METRICS[task]}
    for _ in range(n_examples):
        ex = gen(rng)
        toks = ex.tokens
        # find the answer span: positions with loss_mask, predict greedily
        # with teacher-forced prefix (scores the model's answer tokens)
        ans_positions = np.nonzero(ex.loss_mask)[0]
        if len(ans_positions) == 0 or len(ex.answer) == 0:
            continue
        start = int(ans_positions[0])
        # autoregressive answer decode from the prompt prefix
        cur = list(toks[: start + 1])
        pred = []
        for _ in range(len(ex.answer)):
            padded = np.full(PAD_TO, T.PAD, np.int32)
            padded[: len(cur)] = cur[:PAD_TO]
            lg = logits_fn(jnp.asarray(padded))
            nxt = int(jnp.argmax(lg[len(cur) - 1]))
            pred.append(nxt)
            cur.append(nxt)
        for mname in T.METRICS[task]:
            fn = {
                "em": T.exact_match,
                "f1": T.f1_score,
                "rouge1": T.rouge_1,
                "rougeL": T.rouge_l,
            }[mname]
            scores[mname].append(fn(pred, ex.answer))
    return {m: 100.0 * float(np.mean(v)) for m, v in scores.items() if v}


# ---------------------------------------------------------------------------
# The experiment suite
# ---------------------------------------------------------------------------


def run_all(out_path: str, *, quick: bool = False, seed: int = 0):
    cfg = get_config("sim-tiny")
    steps_base = 150 if quick else 500
    steps_lora = 100 if quick else 400
    bsz = 16
    n_eval = 32 if quick else 96
    # rank scaled to the model width (paper: 16 on 2048-8192 channels;
    # sim-tiny has 128-384, so rank 4 keeps the same rank/width regime)
    RANK = 4
    LORA_LR = 1e-2

    results = {
        "config": cfg.name,
        "steps_base": steps_base,
        "steps_lora": steps_lora,
        "seed": seed,
    }
    t0 = time.time()

    print(f"[1/5] pretraining BitNet base ({steps_base} steps)...")
    params_bit, losses_bit = train_base(
        cfg, bitnet=True, steps=steps_base, batch_size=bsz, seed=seed
    )
    rom = M.rom_image(params_bit, cfg)
    print(f"      final loss {losses_bit[-1]:.3f}, sparsity {M.rom_sparsity(rom):.3f}")

    print(f"[2/5] pretraining full-precision comparator...")
    params_fp, losses_fp = train_base(
        cfg, bitnet=False, steps=steps_base, batch_size=bsz, seed=seed
    )

    # ---- Table I: base vs adapted across all four tasks -------------------
    print("[3/5] Table I: adaptation across tasks...")
    paper_placement = list(M.PAPER_PLACEMENT)
    table1 = {"base": {}, "adapted": {}}
    table1["base"]["ppl"] = eval_ppl(rom, cfg, n_batches=4)
    lora_lm = train_lora(
        rom, cfg, init_lora(cfg, paper_placement, RANK, 6, seed + 1),
        "lm", steps=steps_lora, batch_size=bsz, seed=seed + 1, lr=LORA_LR,
    )
    table1["adapted"]["ppl"] = eval_ppl(rom, cfg, lora=lora_lm, n_batches=4, train=True)
    lora_by_task = {"lm": lora_lm}
    for task in ["qa", "summarization", "drop"]:
        base_scores = eval_task(rom, cfg, task, n_examples=n_eval)
        lora_t = train_lora(
            rom, cfg, init_lora(cfg, paper_placement, RANK, 6, seed + 2),
            task, steps=steps_lora, batch_size=bsz, seed=seed + 2, lr=LORA_LR,
        )
        adapted_scores = eval_task(rom, cfg, task, lora=lora_t, n_examples=n_eval, train=True)
        lora_by_task[task] = lora_t
        for m, v in base_scores.items():
            table1["base"][f"{task}.{m}"] = v
        for m, v in adapted_scores.items():
            table1["adapted"][f"{task}.{m}"] = v
        print(f"      {task}: base {base_scores} -> adapted {adapted_scores}")
    results["table1"] = table1

    # ---- Table II: placement ablation on QA --------------------------------
    print("[4/5] Table II: placement ablation (QA)...")
    placements = {
        "QKGU": ["q", "k", "gate", "up"],
        "D": ["down"],
        "OD": ["o", "down"],
        "VOD": ["v", "o", "down"],
        "ALL": ["q", "k", "v", "o", "gate", "up", "down"],
    }
    table2 = {}
    for label, pl in placements.items():
        lora_p = train_lora(
            rom, cfg, init_lora(cfg, pl, RANK, 6, seed + 3),
            "qa", steps=steps_lora, batch_size=bsz, seed=seed + 3, lr=LORA_LR,
        )
        sc = eval_task(rom, cfg, "qa", lora=lora_p, n_examples=n_eval, train=True)
        # param overhead mirrors rust lora::LoraConfig
        extra = sum(
            ((cfg.d_ff if n == "down" else cfg.d_model)
             + {"k": cfg.n_kv_heads * cfg.head_dim, "v": cfg.n_kv_heads * cfg.head_dim,
                "gate": cfg.d_ff, "up": cfg.d_ff, "down": cfg.d_model}.get(n, cfg.d_model))
            * RANK
            for n in pl
        ) * cfg.n_layers
        table2[label] = {
            "params_pct": 100.0 * extra / cfg.param_count(),
            **sc,
        }
        print(f"      {label}: {table2[label]}")
    results["table2"] = table2

    # ---- Fig 6(a): adapter bit-width sweep (PTQ of the trained QA adapter) -
    print("[5/5] Fig 6: quantization ablations...")
    fig6a = {}
    for bits in [2, 3, 4, 6, 8]:
        lora_q = json_safe_requant(lora_by_task["qa"], bits)
        sc = eval_task(rom, cfg, "qa", lora=lora_q, n_examples=n_eval, train=False)
        fig6a[str(bits)] = sc
        print(f"      {bits}-bit adapter: {sc}")
    results["fig6a"] = fig6a

    # ---- Fig 6(b): BitNet vs full-precision base, fp vs quantized adapter --
    fig6b = {}
    fig6b["bitnet_ppl"] = table1["base"]["ppl"]
    fig6b["fp_ppl"] = eval_ppl(params_fp, cfg, n_batches=4, qat=False)
    lora_fp = train_lora(
        params_fp, cfg, init_lora(cfg, paper_placement, RANK, 6, seed + 4),
        "qa", steps=steps_lora, batch_size=bsz, seed=seed + 4, lr=LORA_LR, qat=False,
    )
    fig6b["bitnet_qa_quant_adapter"] = results["table1"]["adapted"].get("qa.f1", 0.0)
    fig6b["bitnet_qa_fp_adapter"] = eval_task(
        rom, cfg, "qa", lora=json_safe_requant(lora_by_task["qa"], 16), n_examples=n_eval
    ).get("f1", 0.0)
    fig6b["fp_qa_quant_adapter"] = eval_task(
        params_fp, cfg, "qa", lora=lora_fp, n_examples=n_eval, qat=False
    ).get("f1", 0.0)
    fig6b["fp_qa_fp_adapter"] = eval_task(
        params_fp, cfg, "qa", lora=json_safe_requant(lora_fp, 16), n_examples=n_eval,
        qat=False,
    ).get("f1", 0.0)
    results["fig6b"] = fig6b

    results["wall_s"] = time.time() - t0

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path} ({results['wall_s']:.0f}s)")

    # save the trained BitNet base as the serving ROM
    npz_path = os.path.join(os.path.dirname(out_path), "base_model.npz")
    from .aot import flatten_params

    np.savez(npz_path, **{k: np.asarray(v) for k, v in flatten_params(params_bit).items()})
    print(f"wrote {npz_path}")
    return results


def json_safe_requant(lora, bits):
    """Return a copy of the adapter with a different quantization
    bit-width (applied at eval time — PTQ)."""
    out = {"layers": []}
    for layer in lora["layers"]:
        nl = {}
        for name, ad in layer.items():
            nl[name] = dict(ad)
            nl[name]["bits"] = bits
        out["layers"].append(nl)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../results/adaptation.json")
    ap.add_argument("--quick", action="store_true", help="reduced steps (CI)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run_all(args.out, quick=args.quick, seed=args.seed)


if __name__ == "__main__":
    main()
