"""AOT compilation: lower the partitioned BitNet model to HLO text.

This is the "fabrication" step of the CiROM deployment model: weights
are quantized to ternary, baked into the lowered HLO as *constants*, and
the rust runtime loads the resulting executables once at startup. Python
never runs again after this step (``make artifacts`` is a no-op while
inputs are unchanged).

Interchange format is HLO **text** — the image's xla_extension 0.5.1
rejects jax>=0.5 serialized HloModuleProtos (64-bit instruction ids);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Exported executables (for config ``sim-tiny``, prefill bucket P, max_seq
T, L = layers per partition):

  embed_prefill    : tokens i32[P]                         -> (h f32[P,d],)
  embed_decode     : tokens i32[1]                         -> (h f32[1,d],)
  part{p}_prefill  : h[P,d], k[L,T,KV,hd], v[...]          -> (h, k, v)
  part{p}_decode   : h[1,d], k[L,T,KV,hd], v[...], pos i32 -> (h, k, v)
  head_prefill     : h[P,d], idx i32                       -> (logits f32[V],)
  head_decode      : h[1,d]                                -> (logits f32[V],)

plus ``manifest.json`` describing shapes, the weight seed, ROM sparsity
and per-artifact metadata the rust loader validates against.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import get_config

DEFAULT_PREFILL = 64
WEIGHT_SEED = 20260710  # the "mask set": deterministic ROM contents


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so every
    entry point yields a tuple the rust side unpacks uniformly)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default HLO printer elides big
    # constants as `{...}`, which would destroy the baked ROM weights in
    # the text round-trip.
    return comp.as_hlo_text(print_large_constants=True)


def build_rom(cfg, seed: int = WEIGHT_SEED, trained_npz: str | None = None):
    """Produce the ROM image — from a trained checkpoint if provided,
    otherwise from the deterministic random init (serving/perf studies
    don't need a trained model; the adaptation experiments save one)."""
    if trained_npz and os.path.exists(trained_npz):
        import numpy as np

        data = np.load(trained_npz)
        params = unflatten_params(cfg, data)
    else:
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
    return M.rom_image(params, cfg)


def flatten_params(params):
    flat = {"embed": params["embed"], "final_norm": params["final_norm"],
            "lm_head": params["lm_head"]}
    for i, lp in enumerate(params["layers"]):
        for k, v in lp.items():
            flat[f"layers.{i}.{k}"] = v
    return flat


def unflatten_params(cfg, data):
    layers = []
    for i in range(cfg.n_layers):
        layers.append(
            {k: jnp.asarray(data[f"layers.{i}.{k}"]) for k in
             ("attn_norm", "q", "k", "v", "o", "mlp_norm", "gate", "up", "down")}
        )
    return {
        "embed": jnp.asarray(data["embed"]),
        "layers": layers,
        "final_norm": jnp.asarray(data["final_norm"]),
        "lm_head": jnp.asarray(data["lm_head"]),
    }


def lower_all(cfg, rom, prefill: int, use_kernel: bool):
    """Lower every entry point; returns {name: hlo_text}."""
    d = cfg.d_model
    L = cfg.layers_per_partition
    T, KV, hd, V = cfg.max_seq, cfg.n_kv_heads, cfg.head_dim, cfg.vocab_size
    P = prefill

    f32, i32 = jnp.float32, jnp.int32
    h_p = jax.ShapeDtypeStruct((P, d), f32)
    h_d = jax.ShapeDtypeStruct((1, d), f32)
    cache = jax.ShapeDtypeStruct((L, T, KV, hd), f32)
    tok_p = jax.ShapeDtypeStruct((P,), i32)
    tok_d = jax.ShapeDtypeStruct((1,), i32)
    scalar = jax.ShapeDtypeStruct((), i32)

    out = {}

    out["embed_prefill"] = to_hlo_text(
        jax.jit(lambda t: (M.embed_fwd(rom, t),)).lower(tok_p)
    )
    out["embed_decode"] = to_hlo_text(
        jax.jit(lambda t: (M.embed_fwd(rom, t),)).lower(tok_d)
    )

    prefill_positions = jnp.arange(P)

    for p in range(cfg.n_partitions):

        def part_prefill(h, kc, vc, _p=p):
            return M.partition_fwd(
                rom, _p, cfg, h, kc, vc, prefill_positions, use_kernel=use_kernel
            )

        def part_decode(h, kc, vc, pos, _p=p):
            return M.partition_fwd(
                rom, _p, cfg, h, kc, vc, pos[None], use_kernel=use_kernel
            )

        out[f"part{p}_prefill"] = to_hlo_text(
            jax.jit(part_prefill).lower(h_p, cache, cache)
        )
        out[f"part{p}_decode"] = to_hlo_text(
            jax.jit(part_decode).lower(h_d, cache, cache, scalar)
        )

    out["head_prefill"] = to_hlo_text(
        jax.jit(lambda h, idx: (M.head_fwd(rom, cfg, h, idx),)).lower(h_p, scalar)
    )
    out["head_decode"] = to_hlo_text(
        jax.jit(lambda h: (M.head_fwd(rom, cfg, h, 0),)).lower(h_d)
    )

    # Fused whole-model entry points (perf fast path, EXPERIMENTS.md
    # §Perf L3): one PJRT dispatch per token instead of 8. The
    # partitioned executables above remain the pipeline's unit of
    # scheduling; the fused ones serve single-stream generation.
    full_cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, T, KV, hd), f32
    )

    def full_decode(t, kc, vc, pos):
        logits, kc, vc = M.full_fwd(
            rom, cfg, t, pos[None], kc, vc, use_kernel=use_kernel
        )
        return logits[0], kc, vc

    def full_prefill(t, kc, vc, idx):
        logits, kc, vc = M.full_fwd(
            rom, cfg, t, prefill_positions, kc, vc, use_kernel=use_kernel
        )
        return jax.lax.dynamic_slice_in_dim(logits, idx, 1, axis=0)[0], kc, vc

    out["full_decode"] = to_hlo_text(
        jax.jit(full_decode).lower(tok_d, full_cache, full_cache, scalar)
    )
    out["full_prefill"] = to_hlo_text(
        jax.jit(full_prefill).lower(tok_p, full_cache, full_cache, scalar)
    )
    return out


GOLDEN_PROMPT = [1, 5, 17, 42, 99, 7, 3, 250]
GOLDEN_NEW_TOKENS = 16


def golden_trace(cfg, rom):
    """Greedy-decode a fixed prompt through the python model (kernel
    path). The rust runtime must reproduce the exact token sequence and
    near-exact logits — this is the cross-language integration oracle."""
    toks = M.generate_greedy(rom, cfg, GOLDEN_PROMPT, GOLDEN_NEW_TOKENS)
    # Also record the prefill logits row for a tighter numeric check.
    kc, vc = M.empty_caches(cfg)
    logits, _, _ = M.full_fwd(
        rom,
        cfg,
        jnp.asarray(GOLDEN_PROMPT, jnp.int32),
        jnp.arange(len(GOLDEN_PROMPT)),
        kc,
        vc,
        use_kernel=True,
    )
    last = logits[len(GOLDEN_PROMPT) - 1]
    return {
        "prompt": GOLDEN_PROMPT,
        "generated": [int(t) for t in toks],
        "prefill_last_logits": [float(x) for x in last],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", default="sim-tiny")
    ap.add_argument("--prefill", type=int, default=DEFAULT_PREFILL)
    ap.add_argument("--seed", type=int, default=WEIGHT_SEED)
    ap.add_argument(
        "--trained",
        default="../results/base_model.npz",
        help="use this trained checkpoint as ROM contents if it exists",
    )
    ap.add_argument(
        "--no-kernel",
        action="store_true",
        help="lower the pure-jnp path instead of the Pallas kernel path",
    )
    args = ap.parse_args()

    cfg = get_config(args.config)
    rom = build_rom(cfg, args.seed, args.trained)
    sparsity = M.rom_sparsity(rom)
    use_kernel = not args.no_kernel

    os.makedirs(args.out_dir, exist_ok=True)
    texts = lower_all(cfg, rom, args.prefill, use_kernel)

    artifacts = {}
    for name, text in texts.items():
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"  wrote {fname} ({len(text)} chars)")

    manifest = {
        "config": {
            "name": cfg.name,
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff,
            "vocab_size": cfg.vocab_size,
            "max_seq": cfg.max_seq,
            "n_partitions": cfg.n_partitions,
            "layers_per_partition": cfg.layers_per_partition,
            "act_bits": cfg.act_bits,
        },
        "prefill_len": args.prefill,
        "weight_seed": args.seed,
        "trained_checkpoint": bool(
            args.trained and os.path.exists(args.trained)
        ),
        "rom_sparsity": float(sparsity),
        "pallas_kernel": use_kernel,
        "artifacts": artifacts,
    }
    manifest["golden"] = golden_trace(cfg, rom)
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json (sparsity={sparsity:.4f})")


if __name__ == "__main__":
    main()
