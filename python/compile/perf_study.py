"""L1/L2 performance study (EXPERIMENTS.md §Perf).

L1 (Pallas kernel): interpret=True gives CPU-numpy timings that are NOT
a TPU proxy, so the kernel is optimized *structurally*: for each block
shape we report the VMEM working set, the number of HBM↔VMEM transfers
implied by the BlockSpec grid, and the MXU tile alignment — then verify
numerics are block-shape invariant (also covered by pytest).

L2 (lowered graph): audits the HLO of every exported partition — op
histogram, count of dot/convert/quantize ops per layer (catches
accidental recomputation), and the decode-step's sequence-length
dependence.

Usage: python -m compile.perf_study [--out ../results/perf_l1l2.json]
"""

import argparse
import json
import re

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import quant
from .aot import build_rom, lower_all, to_hlo_text
from .configs import get_config
from .kernels.ternary_matmul import ternary_matmul, vmem_bytes


def l1_block_sweep():
    """Structural cost model per block shape for the macro-scale matmul
    (m=64 tokens, k=2048, n=2048 — one BiROMA-sized projection)."""
    m, k, n = 64, 2048, 2048
    vmem_limit = 16 * 2 ** 20
    rows = []
    for bm, bn, bk in [
        (8, 128, 128),
        (64, 128, 128),
        (128, 128, 128),
        (128, 256, 256),
        (128, 512, 512),
        (64, 2048, 64),
        (8, 8, 8),
    ]:
        grid = (
            -(-m // bm),
            -(-n // bn),
            -(-k // bk),
        )
        steps = grid[0] * grid[1] * grid[2]
        # HBM->VMEM traffic: x block per (i,kk), w block per (j,kk)
        x_bytes = grid[0] * grid[2] * bm * bk * 4 * grid[1]  # re-fetched per j
        w_bytes = grid[1] * grid[2] * bk * bn * 4 * grid[0]  # re-fetched per i
        vmem = vmem_bytes(bm, bn, bk)
        rows.append(
            {
                "block": [bm, bn, bk],
                "grid_steps": steps,
                "vmem_bytes": vmem,
                "fits_vmem": vmem <= vmem_limit,
                "hbm_traffic_mb": (x_bytes + w_bytes) / 2 ** 20,
                "mxu_aligned": bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0,
            }
        )
    return {"shape_mkn": [m, k, n], "sweep": rows}


def _op_histogram(hlo_text: str):
    hist = {}
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT )?[%\w.\-]+ = \S+ ([a-z\-]+)\(", line)
        if m:
            hist[m.group(1)] = hist.get(m.group(1), 0) + 1
    return hist


def l2_hlo_audit(cfg_name="sim-tiny", prefill=64):
    cfg = get_config(cfg_name)
    rom = build_rom(cfg)
    texts = lower_all(cfg, rom, prefill, use_kernel=True)
    audit = {}
    for name in ["part0_prefill", "part0_decode", "embed_prefill", "head_decode"]:
        hist = _op_histogram(texts[name])
        audit[name] = {
            "total_ops": sum(hist.values()),
            "dot": hist.get("dot", 0),
            "top5": sorted(hist.items(), key=lambda kv: -kv[1])[:5],
            "bytes": len(texts[name]),
        }
    # invariants the perf pass checks:
    checks = {
        # 7 projections per layer; bit-serial/no-dup quantize means the
        # dot count per decode partition should be small and fixed.
        "decode_dots_per_layer": audit["part0_decode"]["dot"]
        / cfg.layers_per_partition(),
        # decode artifact must not grow with max_seq beyond the cache
        # (attention reads the fixed cache; no quadratic blowup)
        "decode_smaller_than_prefill": audit["part0_decode"]["total_ops"]
        <= audit["part0_prefill"]["total_ops"],
    }
    return {"audit": audit, "checks": checks}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../results/perf_l1l2.json")
    args = ap.parse_args()
    result = {"l1": l1_block_sweep(), "l2": l2_hlo_audit()}
    import os

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, default=str)
    print(json.dumps(result["l1"]["sweep"], indent=1)[:800])
    print(json.dumps(result["l2"]["checks"], indent=1))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
