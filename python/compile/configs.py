"""Model configurations for the BitROM reproduction.

Three tiers (see DESIGN.md §6):

* ``falcon3-1b``   — the paper's deployment target. Used ONLY by the
  analytical area/energy model on the rust side; never instantiated as
  actual arrays here (1.6B params would defeat the point of a CPU repro).
* ``sim-small``    — trainable-in-minutes config used by the adaptation
  experiments (Table I / Table II / Fig 6).
* ``sim-tiny``     — the AOT/serving config: 6 macro partitions (the
  paper's partition count for Falcon3-1B), 1 transformer layer per
  partition, compiled to HLO artifacts executed by the rust coordinator.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """Architecture of a BitNet (Falcon3-style) decoder-only transformer."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int  # grouped-query attention (paper: 4 KV heads)
    d_ff: int
    vocab_size: int
    max_seq: int
    n_partitions: int  # independent BitROM macro partitions (paper: 6)
    rope_theta: float = 10000.0
    # Activation quantization (BitNet a4.8-style hybrid): "int8" or "int4".
    act_bits: int = 8
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def layers_per_partition(self) -> int:
        assert self.n_layers % self.n_partitions == 0
        return self.n_layers // self.n_partitions

    @property
    def gqa_group(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Total weight parameters (embeddings + blocks + head)."""
        d, f = self.d_model, self.d_ff
        kv_dim = self.n_kv_heads * self.head_dim
        attn = d * d + 2 * d * kv_dim + d * d  # Q, K, V, O
        mlp = 3 * d * f  # gate, up, down
        block = attn + mlp + 2 * d  # + two RMSNorm gains
        return self.vocab_size * d * 2 + self.n_layers * block + d


SIM_TINY = ModelConfig(
    name="sim-tiny",
    n_layers=6,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=384,
    vocab_size=256,
    max_seq=128,
    n_partitions=6,
)

SIM_SMALL = ModelConfig(
    name="sim-small",
    n_layers=6,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    d_ff=768,
    vocab_size=512,
    max_seq=256,
    n_partitions=6,
)

# Analytical reference only — never materialized as arrays in python.
FALCON3_1B = ModelConfig(
    name="falcon3-1b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=4,
    d_ff=8192,
    vocab_size=131072,
    max_seq=4096,
    n_partitions=6,
)

CONFIGS = {c.name: c for c in (SIM_TINY, SIM_SMALL, FALCON3_1B)}


def get_config(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown model config {name!r}; have {sorted(CONFIGS)}")
