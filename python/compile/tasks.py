"""Synthetic downstream-task suite — stand-ins for the paper's
WikiText-2/PTB (perplexity), SQuAD (EM/F1), Gigaword (ROUGE-1/L) and
DROP (F1) benchmarks (DESIGN.md §5 substitution log).

Each task emits (tokens, loss_mask, answer_span) examples over the
model's own token space, plus the metric used by the paper for that
benchmark. The tasks are constructed so that a frozen generic base
model is *measurably worse* than an adapted one — which is exactly the
property Table I/II measure.

Token-space layout (vocab ≥ 256):
  0         PAD
  1         BOS
  2         SEP   ("question:" separator)
  3         ANS   ("answer:" marker)
  4         EOS
  10..59    keys      (QA)
  60..109   values    (QA)
  110..169  content words (LM / summarization)
  170..179  digits 0-9 (DROP-style counting)
  180..199  noise words
"""

from dataclasses import dataclass

import numpy as np

PAD, BOS, SEP, ANS, EOS = 0, 1, 2, 3, 4
KEYS = list(range(10, 60))
VALUES = list(range(60, 110))
WORDS = list(range(110, 170))
DIGITS = list(range(170, 180))
NOISE = list(range(180, 200))


@dataclass
class Example:
    """One training/eval example.

    tokens:    [S] int token ids (model input; next-token targets are
               tokens shifted left).
    loss_mask: [S] float — 1.0 where the next-token prediction is
               trained/scored (answer spans for QA-style tasks, all
               content for LM).
    answer:    the reference answer tokens (for EM/F1/ROUGE metrics).
    """

    tokens: np.ndarray
    loss_mask: np.ndarray
    answer: list


# ---------------------------------------------------------------------------
# Task generators
# ---------------------------------------------------------------------------


def lm_example(rng, seq_len=48):
    """Language modeling (WikiText-2/PTB stand-in): a Markov-ish
    templated corpus — word bigrams have structure a model can learn."""
    toks = [BOS]
    w = rng.choice(WORDS)
    while len(toks) < seq_len - 1:
        toks.append(int(w))
        # biased bigram: 70% deterministic successor, 30% random
        if rng.random() < 0.7:
            w = WORDS[((w - WORDS[0]) * 7 + 3) % len(WORDS)]
        else:
            w = rng.choice(WORDS)
    toks.append(EOS)
    toks = np.asarray(toks, np.int32)
    mask = np.ones(len(toks), np.float32)
    mask[-1] = 0.0  # nothing to predict after EOS
    return Example(toks, mask, [])


def qa_example(rng, n_facts=3, n_keys=12, n_values=12):
    """QA (SQuAD stand-in): key-value recall.

    "BOS k1 v1 k2 v2 ... SEP kq ANS vq EOS" — the model must emit the
    value bound to the queried key. EM/F1 over the answer span. The
    key/value spaces are kept small enough that a ~1M-param model can
    master the task, so the adaptation experiments measure adaptation,
    not model capacity."""
    keys = rng.choice(KEYS[:n_keys], size=n_facts, replace=False)
    vals = rng.choice(VALUES[:n_values], size=n_facts, replace=True)
    qi = rng.integers(0, n_facts)
    toks = [BOS]
    for k, v in zip(keys, vals):
        toks += [int(k), int(v)]
    toks += [SEP, int(keys[qi]), ANS, int(vals[qi]), EOS]
    toks = np.asarray(toks, np.int32)
    mask = np.zeros(len(toks), np.float32)
    # train/score only the answer prediction (position of ANS predicts
    # the value; position of the value predicts EOS)
    ans_pos = len(toks) - 3
    mask[ans_pos] = 1.0
    mask[ans_pos + 1] = 1.0
    return Example(toks, mask, [int(vals[qi])])


def summarization_example(rng, n_words=6, n_keep=2, n_vocab=16):
    """Summarization (Gigaword stand-in): emit the marked salient words,
    in order. ROUGE-1/L against the reference selection.

    Salient words are the ones immediately preceded by the salience
    marker token — a learnable copy/compression rule sized for a
    ~1M-param model (small word vocab, fixed marker)."""
    MARK = NOISE[0]
    words = rng.choice(WORDS[:n_vocab], size=n_words, replace=True)
    keep_idx = sorted(rng.choice(n_words, size=n_keep, replace=False))
    toks = [BOS]
    summary = []
    for i, w in enumerate(words):
        if i in keep_idx:
            toks.append(MARK)  # salience marker
            summary.append(int(w))
        toks.append(int(w))
    toks += [SEP] + summary + [EOS]
    toks = np.asarray(toks, np.int32)
    mask = np.zeros(len(toks), np.float32)
    start = len(toks) - len(summary) - 2  # SEP predicts first summary tok
    for i in range(len(summary) + 1):
        mask[start + i] = 1.0
    return Example(toks, mask, summary)


def drop_example(rng, n_items=8):
    """Paragraph comprehension (DROP stand-in): count occurrences of a
    queried word in the passage, answer as a digit token. F1 on the
    answer."""
    target = int(rng.choice(WORDS[:10]))
    count = int(rng.integers(1, 6))
    others = [int(w) for w in rng.choice(WORDS[10:], size=n_items - count)]
    passage = [target] * count + others
    rng.shuffle(passage)
    toks = [BOS] + passage + [SEP, target, ANS, DIGITS[count], EOS]
    toks = np.asarray(toks, np.int32)
    mask = np.zeros(len(toks), np.float32)
    mask[len(toks) - 3] = 1.0
    mask[len(toks) - 2] = 1.0
    return Example(toks, mask, [DIGITS[count]])


TASKS = {
    "lm": lm_example,
    "qa": qa_example,
    "summarization": summarization_example,
    "drop": drop_example,
}


def batch(rng, task: str, batch_size: int, pad_to: int):
    """Generate a padded batch: (tokens [B,S], mask [B,S])."""
    gen = TASKS[task]
    exs = [gen(rng) for _ in range(batch_size)]
    toks = np.full((batch_size, pad_to), PAD, np.int32)
    mask = np.zeros((batch_size, pad_to), np.float32)
    for i, ex in enumerate(exs):
        n = min(len(ex.tokens), pad_to)
        toks[i, :n] = ex.tokens[:n]
        mask[i, : n] = ex.loss_mask[:n]
    return toks, mask, exs


# ---------------------------------------------------------------------------
# Metrics (token-level mirrors of the paper's text metrics)
# ---------------------------------------------------------------------------


def exact_match(pred: list, ref: list) -> float:
    return 1.0 if pred == ref else 0.0


def f1_score(pred: list, ref: list) -> float:
    """Token-level F1 (SQuAD/DROP definition)."""
    if not pred or not ref:
        return 1.0 if pred == ref else 0.0
    common = 0
    ref_counts = {}
    for t in ref:
        ref_counts[t] = ref_counts.get(t, 0) + 1
    for t in pred:
        if ref_counts.get(t, 0) > 0:
            common += 1
            ref_counts[t] -= 1
    if common == 0:
        return 0.0
    p = common / len(pred)
    r = common / len(ref)
    return 2 * p * r / (p + r)


def rouge_1(pred: list, ref: list) -> float:
    """Unigram recall-oriented overlap (ROUGE-1 F1)."""
    return f1_score(pred, ref)


def _lcs(a: list, b: list) -> int:
    dp = [[0] * (len(b) + 1) for _ in range(len(a) + 1)]
    for i in range(len(a)):
        for j in range(len(b)):
            dp[i + 1][j + 1] = (
                dp[i][j] + 1 if a[i] == b[j] else max(dp[i][j + 1], dp[i + 1][j])
            )
    return dp[len(a)][len(b)]


def rouge_l(pred: list, ref: list) -> float:
    """Longest-common-subsequence F1 (ROUGE-L)."""
    if not pred or not ref:
        return 1.0 if pred == ref else 0.0
    l = _lcs(pred, ref)
    if l == 0:
        return 0.0
    p = l / len(pred)
    r = l / len(ref)
    return 2 * p * r / (p + r)


METRICS = {
    "lm": ("ppl",),
    "qa": ("em", "f1"),
    "summarization": ("rouge1", "rougeL"),
    "drop": ("f1",),
}
