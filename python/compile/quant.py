"""Quantizers used throughout the BitROM stack.

* Weights: BitNet b1.58 *absmean* ternary quantization — W is scaled by
  the mean absolute value and rounded to {-1, 0, +1}. The ternary values
  are what gets "fused into the ROM"; the per-tensor scale is a single
  float carried alongside (absorbed into the output dequant).
* Activations: BitNet *absmax* per-token quantization to int8 (or int4
  for the a4.8-style hybrid). Values are kept in float containers holding
  exact integers so that the Pallas kernel's matmuls stay MXU-friendly
  (bf16/f32), while remaining bit-faithful to the hardware datapath.
* LoRA adapters: symmetric k-bit absmax quantization (paper: 6-bit
  weights / 8-bit activations, matching the Falcon3 BitNet config).

All functions are pure jnp and jittable; they are used both by the L2
model and by the pure-jnp reference oracle.
"""

import jax.numpy as jnp


def absmean_ternary(w, eps: float = 1e-8):
    """BitNet b1.58 weight quantizer.

    Returns ``(w_q, scale)`` where ``w_q`` contains exact {-1, 0, +1}
    values (float container) and ``w ≈ w_q * scale``.
    """
    scale = jnp.mean(jnp.abs(w)) + eps
    w_q = jnp.clip(jnp.round(w / scale), -1.0, 1.0)
    return w_q, scale


def absmax_quantize(x, bits: int, axis=-1, eps: float = 1e-8):
    """Symmetric absmax quantization to ``bits`` bits along ``axis``.

    Returns ``(x_q, scale)`` with ``x_q`` holding exact integers in
    [-(2^{b-1}-1), 2^{b-1}-1] (float container) and ``x ≈ x_q * scale``.
    ``scale`` keeps the reduced axis with size 1 for broadcasting.
    """
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, eps) / qmax
    x_q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return x_q, scale


def absmax_int8(x, axis=-1):
    """Per-token int8 activation quantization (BitNet default)."""
    return absmax_quantize(x, 8, axis=axis)


def absmax_int4(x, axis=-1):
    """Per-token int4 activation quantization (BitNet a4.8 hybrid)."""
    return absmax_quantize(x, 4, axis=axis)


def fake_quant(x, bits: int, axis=-1):
    """Quantize-dequantize (straight-through container)."""
    x_q, scale = absmax_quantize(x, bits, axis=axis)
    return x_q * scale


def fake_quant_tensor(w, bits: int):
    """Per-tensor quantize-dequantize (used for LoRA adapter weights)."""
    w_q, scale = quantize_kbit(w, bits)
    return w_q * scale


def quantize_kbit(w, bits: int, eps: float = 1e-8):
    """Per-tensor symmetric k-bit quantizer for LoRA adapter weights."""
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(w))
    scale = jnp.maximum(amax, eps) / qmax
    w_q = jnp.clip(jnp.round(w / scale), -qmax, qmax)
    return w_q, scale


def dequantize(x_q, scale):
    return x_q * scale


def ternary_sparsity(w_q) -> jnp.ndarray:
    """Fraction of exactly-zero weights — the quantity TriMLA's zero-skip
    mode exploits (paper Fig 3)."""
    return jnp.mean(w_q == 0.0)


def pack_trits_base3(w_q):
    """Pack ternary values into base-3 digit pairs — two trits per
    'transistor' exactly as BiROMA stores them (paper Fig 4).

    Input: flat array of {-1,0,+1} with even length. Output: uint8 array
    of half the length, each element in [0, 8] encoding
    ``3*(w0+1) + (w1+1)``. This is the build-time view of the bit-density
    claim; the rust `bitnet` module implements the same packing and the
    two sides round-trip (tested).
    """
    w = jnp.asarray(w_q).reshape(-1)
    assert w.shape[0] % 2 == 0, "pad to even length before packing"
    pair = w.reshape(-1, 2) + 1.0  # {0,1,2}
    return (pair[:, 0] * 3 + pair[:, 1]).astype(jnp.uint8)


def unpack_trits_base3(packed):
    """Inverse of :func:`pack_trits_base3`."""
    p = jnp.asarray(packed).astype(jnp.int32)
    w0 = p // 3 - 1
    w1 = p % 3 - 1
    return jnp.stack([w0, w1], axis=-1).reshape(-1).astype(jnp.float32)
