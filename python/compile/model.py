"""L2: BitNet (Falcon3-style) decoder-only transformer in JAX.

Two execution paths share one set of shapes:

* **Inference / ROM path** (``use_kernel=True``): weights are the baked
  ternary ROM image (exact {-1,0,+1} + per-tensor scale), every linear
  projection goes through the L1 Pallas ``ternary_matmul`` kernel, and
  activations are absmax-int8 quantized per token. This is what
  ``aot.py`` lowers to HLO — weights become constants in the executable,
  which is the CiROM "fused at fabrication" property.
* **Training / QAT path** (``bit_linear_train``): straight-through
  fake-quant on weights and activations, pure-jnp so autodiff is cheap.
  Used by ``train_lora.py`` for the adaptation experiments.

The module also provides the partitioned entry points the rust
coordinator executes: the model is split into ``cfg.n_partitions``
macro partitions of ``cfg.layers_per_partition`` layers each (paper
§V-B: Falcon3-1B → 6 partitions × 3 layers, pipelined over 6 batches).
"""

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from . import quant
from .kernels.ternary_matmul import ternary_matmul
from .kernels.lora import lora_delta

# Projections that can carry a LoRA adapter (paper Table II columns).
PROJS = ("q", "k", "v", "o", "gate", "up", "down")
# The paper's chosen placement: Value + Output + Down (Table II row 4).
PAPER_PLACEMENT = ("v", "o", "down")


# ---------------------------------------------------------------------------
# Parameter initialization (float master weights)
# ---------------------------------------------------------------------------


def init_layer(cfg: ModelConfig, key):
    d, f = cfg.d_model, cfg.d_ff
    kv_dim = cfg.n_kv_heads * cfg.head_dim
    ks = jax.random.split(key, 7)

    def dense(k, fan_in, fan_out):
        return jax.random.normal(k, (fan_in, fan_out), jnp.float32) * (
            fan_in**-0.5
        )

    return {
        "attn_norm": jnp.ones((d,), jnp.float32),
        "q": dense(ks[0], d, d),
        "k": dense(ks[1], d, kv_dim),
        "v": dense(ks[2], d, kv_dim),
        "o": dense(ks[3], d, d),
        "mlp_norm": jnp.ones((d,), jnp.float32),
        "gate": dense(ks[4], d, f),
        "up": dense(ks[5], d, f),
        "down": dense(ks[6], f, d),
    }


def init_params(cfg: ModelConfig, key):
    keys = jax.random.split(key, cfg.n_layers + 2)
    return {
        "embed": jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32
        )
        * 0.02,
        "layers": [init_layer(cfg, keys[1 + i]) for i in range(cfg.n_layers)],
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": jax.random.normal(
            keys[-1], (cfg.d_model, cfg.vocab_size), jnp.float32
        )
        * (cfg.d_model**-0.5),
    }


LINEAR_KEYS = ("q", "k", "v", "o", "gate", "up", "down")


def rom_image(params, cfg: ModelConfig):
    """Bake the float master weights into the ternary ROM image.

    Every linear projection becomes ``(w_q ∈ {-1,0,+1}, scale)`` — the
    contents of the BiROMA arrays. Norm gains, embeddings and the LM head
    stay full precision (the paper's auxiliary arithmetic processor
    handles those)."""
    layers = []
    for lp in params["layers"]:
        lq = {"attn_norm": lp["attn_norm"], "mlp_norm": lp["mlp_norm"]}
        for name in LINEAR_KEYS:
            w_q, scale = quant.absmean_ternary(lp[name])
            lq[name] = {"w_q": w_q, "scale": scale}
        layers.append(lq)
    return {
        "embed": params["embed"],
        "layers": layers,
        "final_norm": params["final_norm"],
        "lm_head": params["lm_head"],
    }


def rom_sparsity(rom) -> float:
    """Overall zero-weight fraction of the ROM image (TriMLA skip rate)."""
    total, zeros = 0, 0
    for lq in rom["layers"]:
        for name in LINEAR_KEYS:
            w = lq[name]["w_q"]
            total += w.size
            zeros += int(jnp.sum(w == 0.0))
    return zeros / max(total, 1)


# ---------------------------------------------------------------------------
# Core ops
# ---------------------------------------------------------------------------


def rms_norm(x, gain, eps: float):
    x32 = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 / rms) * gain


def rope_freqs(cfg: ModelConfig):
    hd = cfg.head_dim
    inv = 1.0 / (
        cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    )
    return inv  # [hd/2]


def apply_rope(x, positions, cfg: ModelConfig):
    """x: [S, H, hd]; positions: [S] absolute token positions."""
    inv = rope_freqs(cfg)
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]  # [S, hd/2]
    cos = jnp.cos(ang)[:, None, :]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def bit_linear(x, w_rom, cfg: ModelConfig, use_kernel: bool):
    """Frozen ternary projection through the macro MAC.

    x: [S, fan_in]; returns [S, fan_out] f32."""
    x_q, x_scale = quant.absmax_quantize(x, cfg.act_bits)
    if use_kernel:
        return ternary_matmul(x_q, w_rom["w_q"], x_scale, w_rom["scale"])
    return (
        jnp.dot(x_q, w_rom["w_q"], preferred_element_type=jnp.float32)
        * x_scale
        * w_rom["scale"]
    )


def _ste(x, qx):
    """Straight-through estimator: forward qx, backward identity."""
    return x + jax.lax.stop_gradient(qx - x)


def bit_linear_train(x, w, cfg: ModelConfig):
    """QAT path: fake-quant weights (absmean ternary) and activations
    (absmax int-``act_bits``) with STE gradients."""
    w_q, w_scale = quant.absmean_ternary(w)
    w_fq = _ste(w, w_q * w_scale)
    x_q, x_scale = quant.absmax_quantize(x, cfg.act_bits)
    x_fq = _ste(x, x_q * x_scale)
    return jnp.dot(x_fq, w_fq)


def lora_apply(x, adapter, cfg: ModelConfig, use_kernel: bool, train: bool):
    """Adapter delta for one projection. ``adapter`` holds float A
    ([fan_in, r]) and B ([r, fan_out]) plus (alpha, rank, weight bits).

    Inference quantizes A/B to ``bits`` (paper: 6) and activations to 8b;
    training fake-quants both with STE."""
    alpha, rank, bits = adapter["alpha"], adapter["rank"], adapter["bits"]
    if train:
        a = _ste(adapter["a"], quant.fake_quant_tensor(adapter["a"], bits))
        b = _ste(adapter["b"], quant.fake_quant_tensor(adapter["b"], bits))
        x8 = _ste(x, quant.fake_quant(x, 8))
        return jnp.dot(jnp.dot(x8, a), b) * (alpha / rank)
    a_q, a_s = quant.quantize_kbit(adapter["a"], bits)
    b_q, b_s = quant.quantize_kbit(adapter["b"], bits)
    x8 = quant.fake_quant(x, 8)
    if use_kernel:
        return lora_delta(x8, a_q, b_q, a_s, b_s, alpha=alpha, rank=rank)
    return jnp.dot(jnp.dot(x8, a_q * a_s), b_q * b_s) * (alpha / rank)


def proj(x, layer, name, cfg, use_kernel, lora_layer=None, train=False, qat=True):
    """One projection = frozen BitLinear + optional LoRA delta.

    Dispatch on the weight container: a ROM entry (dict with ``w_q``)
    always goes through the quantized macro path; a raw float matrix is
    either QAT-fake-quantized (``qat=True``, the BitNet training path)
    or a plain dense projection (``qat=False``, the full-precision
    comparator of Fig 6(b))."""
    w = layer[name]
    if isinstance(w, dict):
        y = bit_linear(x, w, cfg, use_kernel)
    elif qat:
        y = bit_linear_train(x, w, cfg)
    else:
        y = jnp.dot(x, w)
    if lora_layer is not None and name in lora_layer:
        y = y + lora_apply(x, lora_layer[name], cfg, use_kernel, train)
    return y


# ---------------------------------------------------------------------------
# Transformer block with KV cache
# ---------------------------------------------------------------------------


def attention(
    q, k_cache, v_cache, q_positions, cfg: ModelConfig
):
    """GQA attention over the (fixed-size) KV cache.

    q: [S, n_heads, hd]; caches: [max_seq, n_kv_heads, hd];
    q_positions: [S] absolute positions. A cache slot ``t`` is visible to
    the query at position ``p`` iff ``t <= p`` — this single causal rule
    also guarantees that stale/padded cache slots are never read (they
    are always overwritten before becoming visible; see DESIGN.md §7.4).
    """
    S, H, hd = q.shape
    G = cfg.gqa_group
    k = jnp.repeat(k_cache, G, axis=1)  # [T, H, hd]
    v = jnp.repeat(v_cache, G, axis=1)
    scores = jnp.einsum("shd,thd->hst", q, k) / jnp.sqrt(float(hd))
    t_idx = jnp.arange(cfg.max_seq)[None, None, :]
    mask = t_idx <= q_positions[None, :, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hst,thd->shd", probs, v)
    return out.reshape(S, H * hd)


def block_fwd(
    h,
    layer,
    k_cache,
    v_cache,
    positions,
    cfg: ModelConfig,
    use_kernel: bool = False,
    lora_layer=None,
    train: bool = False,
    qat: bool = True,
):
    """One transformer block. h: [S, d]; caches [max_seq, kv, hd];
    positions: [S] absolute. Returns (h, k_cache, v_cache)."""
    S = h.shape[0]
    hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads

    x = rms_norm(h, layer["attn_norm"], cfg.norm_eps)
    q = proj(x, layer, "q", cfg, use_kernel, lora_layer, train, qat).reshape(S, H, hd)
    k = proj(x, layer, "k", cfg, use_kernel, lora_layer, train, qat).reshape(S, KV, hd)
    v = proj(x, layer, "v", cfg, use_kernel, lora_layer, train, qat).reshape(S, KV, hd)

    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)

    # Scatter the new K/V rows into the cache at their absolute positions.
    k_cache = k_cache.at[positions].set(k)
    v_cache = v_cache.at[positions].set(v)

    attn = attention(q, k_cache, v_cache, positions, cfg)
    h = h + proj(attn, layer, "o", cfg, use_kernel, lora_layer, train, qat)

    x = rms_norm(h, layer["mlp_norm"], cfg.norm_eps)
    g = proj(x, layer, "gate", cfg, use_kernel, lora_layer, train, qat)
    u = proj(x, layer, "up", cfg, use_kernel, lora_layer, train, qat)
    ff = jax.nn.silu(g) * u  # SwiGLU (Falcon3 family)
    h = h + proj(ff, layer, "down", cfg, use_kernel, lora_layer, train, qat)
    return h, k_cache, v_cache


# ---------------------------------------------------------------------------
# Partitioned entry points (what aot.py lowers, what rust executes)
# ---------------------------------------------------------------------------


def embed_fwd(rom, tokens):
    """tokens: [S] i32 → h [S, d]."""
    return rom["embed"][tokens]


def partition_fwd(
    rom,
    part_idx: int,
    cfg: ModelConfig,
    h,
    k_caches,
    v_caches,
    positions,
    use_kernel: bool = False,
    lora=None,
    train: bool = False,
    qat: bool = True,
):
    """Run partition ``part_idx`` (``layers_per_partition`` consecutive
    layers). caches: [L_p, max_seq, kv, hd]. Returns (h, k_caches,
    v_caches)."""
    L = cfg.layers_per_partition
    base = part_idx * L
    new_k, new_v = [], []
    for i in range(L):
        layer = rom["layers"][base + i]
        lora_layer = None if lora is None else lora["layers"][base + i]
        h, kc, vc = block_fwd(
            h,
            layer,
            k_caches[i],
            v_caches[i],
            positions,
            cfg,
            use_kernel,
            lora_layer,
            train,
            qat,
        )
        new_k.append(kc)
        new_v.append(vc)
    return h, jnp.stack(new_k), jnp.stack(new_v)


def head_fwd(rom, cfg: ModelConfig, h, idx):
    """Final RMSNorm + LM head at row ``idx`` of h. Returns [vocab]."""
    row = jax.lax.dynamic_slice_in_dim(h, idx, 1, axis=0)
    x = rms_norm(row, rom["final_norm"], cfg.norm_eps)
    return jnp.dot(x, rom["lm_head"])[0]


def full_fwd(
    rom,
    cfg: ModelConfig,
    tokens,
    positions,
    k_caches,
    v_caches,
    use_kernel: bool = False,
    lora=None,
    train: bool = False,
    qat: bool = True,
):
    """Whole-model forward (all partitions) — used by tests and the
    adaptation experiments. caches: [n_layers, max_seq, kv, hd].
    Returns (logits [S, vocab], k_caches, v_caches)."""
    h = embed_fwd(rom, tokens)
    L = cfg.layers_per_partition
    nk, nv = [], []
    for p in range(cfg.n_partitions):
        h, kc, vc = partition_fwd(
            rom,
            p,
            cfg,
            h,
            k_caches[p * L : (p + 1) * L],
            v_caches[p * L : (p + 1) * L],
            positions,
            use_kernel,
            lora,
            train,
            qat,
        )
        nk.append(kc)
        nv.append(vc)
    h = rms_norm(h, rom["final_norm"], cfg.norm_eps)
    logits = jnp.dot(h, rom["lm_head"])
    return logits, jnp.concatenate(nk), jnp.concatenate(nv)


def empty_caches(cfg: ModelConfig, n_layers: Optional[int] = None):
    L = cfg.n_layers if n_layers is None else n_layers
    shape = (L, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def generate_greedy(rom, cfg: ModelConfig, prompt, n_new: int, lora=None):
    """Reference auto-regressive loop (prefill + greedy decode) — the
    python-side oracle the rust coordinator is integration-tested
    against."""
    k_caches, v_caches = empty_caches(cfg)
    S = len(prompt)
    tokens = jnp.asarray(prompt, jnp.int32)
    logits, k_caches, v_caches = full_fwd(
        rom, cfg, tokens, jnp.arange(S), k_caches, v_caches, lora=lora
    )
    out = []
    tok = int(jnp.argmax(logits[S - 1]))
    out.append(tok)
    for step in range(1, n_new):
        pos = S + step - 1
        logits, k_caches, v_caches = full_fwd(
            rom,
            cfg,
            jnp.asarray([tok], jnp.int32),
            jnp.asarray([pos]),
            k_caches,
            v_caches,
            lora=lora,
        )
        tok = int(jnp.argmax(logits[0]))
        out.append(tok)
    return out
